"""Continuous-batching evaluation scheduler.

Ground-truth labeling (XLA synthesis + behavioral simulation) dominates
every campaign's wall clock, so the service routes ALL label requests
through one scheduler that

  * answers from the label store when it can (cross-campaign and
    cross-process reuse),
  * **dedupes identical genomes in flight** — if campaign B asks for a
    genome campaign A is already synthesizing, B rides A's future
    instead of paying a second compile,
  * **coalesces** outstanding misses from all concurrent campaigns into
    batches (the JetStream/vLLM continuous-batching idiom: a short
    admission window, then drain up to ``max_batch`` compatible
    requests) and fans them out to a thread worker pool.

Requests are only batched together when they share an evaluation
context (same accelerator / library / QoR signature) — a batch is one
``ctx.ground_truth`` call.

``backend`` selects where a batch's ground truth runs: ``"thread"``
labels in-process on the dispatching worker thread (fine for cheap
contexts); ``"process"`` fans the batch out to a spawn-safe worker
process pool (``workers.ProcessPoolLabeler``) — the only way the
GIL-bound behavioral simulation and GIL-holding XLA tracing actually
parallelize.  ``"fleet"`` leases batches to remote workers registered
with the embedded ``repro.fleet`` orchestrator (multi-HOST labeling);
``fleet_fallback`` picks what runs a batch when the fleet is empty or
the context is not portable.  Contexts a fresh process/host cannot
rebuild by name fall back to the in-process path transparently.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .. import faults, obs
from .store import LABEL_KEYS, EvalContext, LabelStore

__all__ = ["EvalScheduler", "gather_futures"]


def gather_futures(futures: List[Future], callback) -> None:
    """Invoke ``callback(recs, exc)`` exactly once when every future has
    resolved — the non-blocking counterpart of ``[f.result() for f in
    futures]`` that lets a campaign release its worker thread while its
    labels are in flight.  ``recs`` is the in-order result list (None on
    failure, with ``exc`` the first exception encountered)."""
    if not futures:
        callback([], None)
        return
    lock = threading.Lock()
    remaining = [len(futures)]

    def _one_done(_f: Future) -> None:
        with lock:
            remaining[0] -= 1
            if remaining[0]:
                return
        try:
            recs = [f.result() for f in futures]
        except Exception as exc:  # noqa: BLE001 - surfaced via callback
            callback(None, exc)
            return
        callback(recs, None)

    for f in futures:
        f.add_done_callback(_one_done)


@dataclass
class _Entry:
    """One in-flight unique genome: a shared future plus the campaigns
    waiting on it (for coalescing accounting)."""

    key: str
    genome: np.ndarray
    ctx: EvalContext
    origin: Optional[str] = None  # campaign that pays the ground truth
    future: Future = field(default_factory=Future)
    campaigns: set = field(default_factory=set)
    # trace context captured at submit() so the batch span (run on a
    # pool thread) links back to the submitting campaign's trace
    wire: Optional[dict] = None


class EvalScheduler:
    """Coalescing label scheduler over a ``LabelStore``.

    ``label(ctx, genomes)`` is the blocking batch interface campaigns
    inject into ``run_dse`` as their labeler; ``submit`` is the
    future-based building block underneath it."""

    def __init__(
        self,
        store: LabelStore,
        *,
        n_workers: int = 2,
        max_batch: int = 32,
        max_wait_s: float = 0.02,
        backend: str = "thread",
        process_workers: Optional[int] = None,
        chunk_size: Optional[int] = None,
        synth_cache_path: Optional[str] = None,
        fleet: Optional[object] = None,
        fleet_fallback: str = "thread",
        lease_ttl_s: float = 30.0,
        heartbeat_ttl_s: float = 15.0,
        fleet_chunk: Optional[int] = None,
    ):
        if backend not in ("thread", "process", "fleet"):
            raise ValueError(
                f"backend must be 'thread', 'process' or 'fleet', "
                f"got {backend!r}"
            )
        if fleet_fallback not in ("thread", "process"):
            raise ValueError(
                f"fleet_fallback must be 'thread' or 'process', "
                f"got {fleet_fallback!r}"
            )
        self.store = store
        if hasattr(store, "register_metrics"):
            store.register_metrics()
        self.backend = backend
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self._proc = None
        self.fleet = None
        if backend == "fleet":
            from ..fleet.orchestrator import FleetCoordinator

            self.fleet = fleet if fleet is not None else FleetCoordinator(
                lease_ttl_s=lease_ttl_s, heartbeat_ttl_s=heartbeat_ttl_s,
                chunk_size=fleet_chunk,
            )
        if backend == "process" or (backend == "fleet"
                                    and fleet_fallback == "process"):
            from .workers import ProcessPoolLabeler

            self._proc = ProcessPoolLabeler(
                process_workers if process_workers is not None else n_workers,
                chunk_size=chunk_size,
                synth_cache_path=synth_cache_path,
            )
        self._pool = ThreadPoolExecutor(n_workers, thread_name_prefix="eval")
        self._cv = threading.Condition()
        self._pending: deque = deque()          # _Entry awaiting dispatch
        self._inflight: Dict[str, _Entry] = {}  # key -> entry (pending or running)
        self._stopped = False
        # accounting — registry instruments, not plain ints: per-thread
        # sharded counters are incrementable outside _cv (worker threads
        # never contend with stats() scrapes) and double as the
        # GET /metrics substrate.  Running counters only: the service is
        # long-lived, so per-batch history would grow unbounded.
        reg = obs.REGISTRY
        self.n_requests = reg.counter(
            "repro_sched_requests_total", "label requests submitted")
        self.n_store_hits = reg.counter(
            "repro_sched_store_hits_total", "requests answered by the store")
        self.n_inflight_hits = reg.counter(
            "repro_sched_inflight_hits_total",
            "requests deduped onto an in-flight genome")
        self.n_labeled = reg.counter(
            "repro_sched_labeled_total", "genomes ground-truth labeled")
        self.n_batches = reg.counter(
            "repro_sched_batches_total", "label batches dispatched")
        self.n_coalesced_batches = reg.counter(
            "repro_sched_coalesced_batches_total",
            "batches serving more than one campaign")
        self.n_process_batches = reg.counter(
            "repro_sched_process_batches_total",
            "batches labeled on the process pool")
        self.n_process_fallbacks = reg.counter(
            "repro_sched_process_fallbacks_total",
            "batches that fell back from the process pool")
        self.n_fleet_batches = reg.counter(
            "repro_sched_fleet_batches_total", "batches leased to the fleet")
        self.n_fleet_fallbacks = reg.counter(
            "repro_sched_fleet_fallbacks_total",
            "batches that fell back from the fleet")
        self.batch_size = reg.histogram(
            "repro_sched_batch_size", "genomes per dispatched batch",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256))
        self.batch_seconds = reg.histogram(
            "repro_sched_batch_seconds",
            "ground truth + store write latency per batch")
        self.queue_depth = reg.gauge(
            "repro_sched_pending", "entries awaiting dispatch")
        self.inflight_gauge = reg.gauge(
            "repro_sched_inflight", "unique genomes pending or running")
        self.per_campaign: Dict[str, Dict[str, int]] = {}
        self._batcher = threading.Thread(
            target=self._batch_loop, name="eval-batcher", daemon=True
        )
        self._batcher.start()

    # ------------------------------------------------------------------
    def _campaign_stats(self, campaign: Optional[str]) -> Dict[str, int]:
        cid = campaign or "_anon"
        if cid not in self.per_campaign:
            self.per_campaign[cid] = {
                "requests": 0, "store_hits": 0, "inflight_hits": 0,
                "labeled": 0,
            }
        return self.per_campaign[cid]

    def submit(
        self,
        ctx: EvalContext,
        genomes: np.ndarray,
        *,
        campaign: Optional[str] = None,
    ) -> List[Future]:
        """One future per genome row; resolved futures for store hits."""
        genomes = np.atleast_2d(np.asarray(genomes, dtype=np.int64))
        futures: List[Future] = []
        to_enqueue: List[_Entry] = []
        wire = obs.wire_context()
        with self._cv:
            if self._stopped:
                raise RuntimeError("scheduler is shut down")
            cstats = self._campaign_stats(campaign)
            for g in genomes:
                self.n_requests.inc()
                cstats["requests"] += 1
                key = ctx.key(g)
                ent = self._inflight.get(key)
                if ent is not None:
                    # identical genome already queued/being labeled:
                    # share its future (in-flight dedup)
                    self.n_inflight_hits.inc()
                    cstats["inflight_hits"] += 1
                    if campaign is not None:
                        ent.campaigns.add(campaign)
                    futures.append(ent.future)
                    continue
                rec = self.store.get(key)
                if rec is not None:
                    self.n_store_hits.inc()
                    cstats["store_hits"] += 1
                    f: Future = Future()
                    f.set_result(rec)
                    futures.append(f)
                    continue
                ent = _Entry(key=key, genome=np.array(g), ctx=ctx,
                             origin=campaign, wire=wire)
                if campaign is not None:
                    ent.campaigns.add(campaign)
                self._inflight[key] = ent
                to_enqueue.append(ent)
                futures.append(ent.future)
            self._pending.extend(to_enqueue)
            self.queue_depth.set(len(self._pending))
            self.inflight_gauge.set(len(self._inflight))
            if to_enqueue:
                self._cv.notify_all()
        return futures

    def label(
        self,
        ctx: EvalContext,
        genomes: np.ndarray,
        *,
        campaign: Optional[str] = None,
        timeout: Optional[float] = None,
    ) -> Dict[str, np.ndarray]:
        """Blocking batch labeling — the drop-in ``run_dse`` labeler."""
        genomes = np.atleast_2d(np.asarray(genomes, dtype=np.int64))
        futures = self.submit(ctx, genomes, campaign=campaign)
        recs = [f.result(timeout=timeout) for f in futures]
        return {
            k: np.array([float(r[k]) for r in recs]) for k in LABEL_KEYS
        }

    # ------------------------------------------------------------------
    def _batch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._stopped:
                    self._cv.wait()
                if self._stopped and not self._pending:
                    return
                # pending campaigns BEFORE the admission window: the
                # window only exists to coalesce concurrent campaigns,
                # so a lone campaign skips it (single-campaign latency —
                # every batch used to eat the full wait)
                pending_campaigns = {e.origin for e in self._pending}
            if self.max_wait_s > 0 and len(pending_campaigns) > 1:
                time.sleep(self.max_wait_s)
            batch: List[_Entry] = []
            bad: List = []  # (entry, exc) whose ctx.fingerprint raised
            with self._cv:
                if not self._pending:
                    continue
                # drain up to max_batch entries sharing the head's context
                head_fp = None
                keep: deque = deque()
                while self._pending:
                    ent = self._pending.popleft()
                    try:
                        fp = ent.ctx.fingerprint
                    except Exception as exc:  # noqa: BLE001 - caller ctx
                        self._inflight.pop(ent.key, None)
                        bad.append((ent, exc))
                        continue
                    if head_fp is None:
                        head_fp = fp
                    if len(batch) < self.max_batch and fp == head_fp:
                        batch.append(ent)
                    else:
                        keep.append(ent)
                self._pending = keep
                self.queue_depth.set(len(self._pending))
            # a misbehaving caller context must fail its waiters, never
            # kill the batcher thread
            for ent, exc in bad:
                ent.future.set_exception(exc)
            if not batch:
                continue
            try:
                self._pool.submit(self._run_batch, batch)
            except RuntimeError as exc:
                # pool already shut down (shutdown(wait=False) race):
                # fail the waiters instead of leaving futures unresolved
                with self._cv:
                    for e in batch:
                        self._inflight.pop(e.key, None)
                for e in batch:
                    e.future.set_exception(exc)

    def _ground_truth(self, ctx: EvalContext, genomes: np.ndarray,
                      sp=None):
        """One batched ground-truth call, on the configured backend."""
        if self.fleet is not None:
            # empty fleet / unportable context degrades to the fallback
            # backend below (counted, so /stats shows the degradation)
            if self.fleet.eligible(ctx):
                self.n_fleet_batches.inc()
                if sp is not None:
                    sp.set(backend="fleet")
                return self.fleet.label(ctx, genomes)
            self.n_fleet_fallbacks.inc()
        if self._proc is not None:
            if self._proc.can_label(ctx):
                self.n_process_batches.inc()
                if sp is not None:
                    sp.set(backend="process")
                return self._proc.label(ctx, genomes)
            self.n_process_fallbacks.inc()
        if sp is not None:
            sp.set(backend="thread")
        return ctx.ground_truth(genomes)

    def _run_batch(self, batch: List[_Entry]) -> None:
        ctx = batch[0].ctx
        head = batch[0]
        t0 = time.perf_counter()
        with obs.attach(head.wire), \
                obs.span("sched.batch", n=len(batch),
                         origin=head.origin) as sp:
            try:
                faults.hit("sched.dispatch", n=len(batch),
                           origin=head.origin)
                genomes = np.stack([e.genome for e in batch])
                labels = self._ground_truth(ctx, genomes, sp)
                recs = [
                    {k: float(labels[k][i]) for k in LABEL_KEYS}
                    for i in range(len(batch))
                ]
                # one lock acquisition + one buffered write for the batch
                self.store.put_many(
                    (e.key, rec) for e, rec in zip(batch, recs)
                )
            except Exception as exc:
                # label OR store failure: fail every waiter instead of
                # leaving dead inflight entries that hang future dedup hits
                sp.set(outcome="error", error=type(exc).__name__)
                with self._cv:
                    for e in batch:
                        self._inflight.pop(e.key, None)
                    self.inflight_gauge.set(len(self._inflight))
                for e in batch:
                    e.future.set_exception(exc)
                return
            with self._cv:
                # e.campaigns is mutated by submit() under this lock, so
                # the union must happen here too
                campaigns = set()
                for e in batch:
                    campaigns |= e.campaigns
                    # the originating request pays ground truth — accounted
                    # on success so failed batches don't overstate work
                    self._campaign_stats(e.origin)["labeled"] += 1
                for e in batch:
                    self._inflight.pop(e.key, None)
                self.inflight_gauge.set(len(self._inflight))
            self.n_labeled.inc(len(batch))
            self.n_batches.inc()
            if len(campaigns) > 1:
                self.n_coalesced_batches.inc()
            self.batch_size.observe(len(batch))
            self.batch_seconds.observe(time.perf_counter() - t0)
            sp.set(outcome="ok", campaigns=len(campaigns))
        for rec, e in zip(recs, batch):
            e.future.set_result(rec)

    # ------------------------------------------------------------------
    def stats(self) -> Dict:
        # per-backend labeler counters (the process pool aggregates its
        # workers' synthesis-engine counters); taken outside the cv so a
        # slow pool can't stall submitters
        labeler = self._proc.stats() if self._proc is not None else None
        fleet = self.fleet.stats() if self.fleet is not None else None
        # counter reads are registry-instrument scrapes — no _cv needed,
        # so a long-running batch can never stall a stats() poller; only
        # the per-campaign dict still wants the lock
        requests = int(self.n_requests.value)
        store_hits = int(self.n_store_hits.value)
        inflight_hits = int(self.n_inflight_hits.value)
        n_batches = int(self.n_batches.value)
        with self._cv:
            per_campaign = {k: dict(v) for k, v in self.per_campaign.items()}
        return {
            "backend": self.backend,
            "labeler": labeler,
            "fleet": fleet,
            "fleet_batches": int(self.n_fleet_batches.value),
            "fleet_fallbacks": int(self.n_fleet_fallbacks.value),
            "process_batches": int(self.n_process_batches.value),
            "process_fallbacks": int(self.n_process_fallbacks.value),
            "requests": requests,
            "store_hits": store_hits,
            "inflight_dedup_hits": inflight_hits,
            "labeled": int(self.n_labeled.value),
            "batches": n_batches,
            "coalesced_batches": int(self.n_coalesced_batches.value),
            "mean_batch_size": (
                self.batch_size.sum / n_batches
            ) if n_batches else 0.0,
            "label_hit_rate": (
                (store_hits + inflight_hits) / requests
            ) if requests else 0.0,
            "per_campaign": per_campaign,
            "store": self.store.stats(),
        }

    def campaign_stats(self, campaign: str) -> Optional[Dict[str, int]]:
        """One campaign's labeling counters — O(1), unlike stats()."""
        with self._cv:
            s = self.per_campaign.get(campaign)
            return dict(s) if s is not None else None

    def forget_campaign(self, campaign: str) -> None:
        """Drop a retired campaign's per-campaign accounting (the
        global counters keep its contribution)."""
        with self._cv:
            self.per_campaign.pop(campaign, None)

    def shutdown(self, *, wait: bool = True) -> None:
        with self._cv:
            self._stopped = True
            self._cv.notify_all()
        if wait:
            self._batcher.join(timeout=5)
        if self.fleet is not None:
            # first: a pool thread blocked in fleet.label() reclaims its
            # remaining chunks in-process and returns, so the pool join
            # below cannot deadlock on a starved fleet
            self.fleet.shutdown(wait=wait)
        self._pool.shutdown(wait=wait)
        if self._proc is not None:
            self._proc.shutdown(wait=wait)
