"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution; the vision frontend is
a STUB: input_specs() provides precomputed patch embeddings prepended to
the text sequence [arXiv:2409.12191]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    rope_style="mrope", frontend="vision", frontend_len=256,
    notes="M-RoPE stub: temporal/h/w position ids collapse to text "
          "positions for the backbone dry-run (DESIGN.md).",
)
