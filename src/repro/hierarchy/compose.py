"""Stage-front composition: per-stage Pareto fronts -> application candidates.

The autoAx decomposition: search each component, keep its front, compose
fronts instead of searching the product space.  Composition combines
objective vectors (minimization convention throughout, as core.pareto):

  * hardware objectives (energy, latency, flops, ...) — summed: stage
    deployments execute back-to-back, and the marginal-energy model is
    separable across stages (synth.synthesize_variant),
  * the QoR column (``-psnr``) — additive noise power:
        psnr_c = -10*log10(sum_i 10^(-psnr_i/10))
    i.e. stage error signals are treated as independent additive noise.
    This is an *estimate* used only to rank candidates; the surviving
    candidates are re-labeled end-to-end by search.py.

Both maps are monotone in every stage input, so a dominated partial
composition can never complete into a non-dominated full composition —
the incremental fold below prunes to the non-dominated set after each
stage and never materializes the full cross-product.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from ..core.pareto import non_dominated_mask

__all__ = [
    "StageFront",
    "ComposeStats",
    "ComposeResult",
    "compose_qor",
    "truncate_front",
    "compose_fronts",
]


@dataclass(frozen=True)
class StageFront:
    """One stage's Pareto front: stage-local genomes + objectives (n, m),
    minimization convention (the QoR column is ``-psnr``)."""

    genomes: np.ndarray
    objectives: np.ndarray

    def __post_init__(self):
        assert len(self.genomes) == len(self.objectives)
        assert len(self.genomes) > 0, "a stage front cannot be empty"


@dataclass
class ComposeStats:
    stage_sizes: List[int] = field(default_factory=list)      # as given
    truncated_sizes: List[int] = field(default_factory=list)  # after k_per_stage
    cross_product_size: float = 0.0   # full product of truncated sizes
    pairs_evaluated: int = 0          # partial compositions materialized
    survivors: int = 0


@dataclass
class ComposeResult:
    """``indices[t, i]`` selects the row of stage ``i``'s (truncated)
    front used by candidate ``t``; ``objectives`` are the composed
    estimates; ``stage_genomes[i]`` is the truncated front ``i`` genome
    array the indices point into."""

    indices: np.ndarray           # (n_candidates, n_stages) int
    objectives: np.ndarray        # (n_candidates, m)
    stage_genomes: List[np.ndarray]
    stats: ComposeStats


def compose_qor(neg_psnr_a: np.ndarray, neg_psnr_b: np.ndarray) -> np.ndarray:
    """Combine two ``-psnr`` columns by additive noise power (monotone
    increasing in both arguments, hence pruning-safe)."""
    return 10.0 * np.log10(
        np.power(10.0, neg_psnr_a / 10.0) + np.power(10.0, neg_psnr_b / 10.0)
    )


def _combine(a: np.ndarray, b: np.ndarray, qor_index: Optional[int]) -> np.ndarray:
    """Pairwise composition: (n, m) x (k, m) -> (n*k, m)."""
    out = a[:, None, :] + b[None, :, :]
    if qor_index is not None:
        out[:, :, qor_index] = compose_qor(
            a[:, None, qor_index], b[None, :, qor_index]
        )
    return out.reshape(-1, a.shape[1])


def truncate_front(objectives: np.ndarray, k: Optional[int],
                   *, sort_index: int = 0) -> np.ndarray:
    """Indices of at most ``k`` points spread evenly along the front
    (sorted by ``sort_index``), always keeping both extremes."""
    n = len(objectives)
    order = np.argsort(np.asarray(objectives)[:, sort_index], kind="stable")
    if k is None or n <= k:
        return order
    pick = np.unique(np.round(np.linspace(0, n - 1, k)).astype(np.int64))
    return order[pick]


def compose_fronts(
    fronts: Sequence[StageFront],
    *,
    qor_index: Optional[int] = 0,
    k_per_stage: Optional[int] = None,
    max_survivors: Optional[int] = None,
) -> ComposeResult:
    """Fold the stage fronts left-to-right with incremental non-dominated
    pruning.  ``k_per_stage`` truncates each stage front before the fold;
    ``max_survivors`` additionally caps the candidate set after each
    prune (evenly spaced along the front) to bound the fold itself."""
    assert len(fronts) >= 1
    stats = ComposeStats(stage_sizes=[len(f.genomes) for f in fronts])

    trunc_obj: List[np.ndarray] = []
    trunc_gen: List[np.ndarray] = []
    for f in fronts:
        sel = truncate_front(f.objectives, k_per_stage,
                             sort_index=qor_index if qor_index is not None else 0)
        trunc_obj.append(np.asarray(f.objectives, dtype=np.float64)[sel])
        trunc_gen.append(np.asarray(f.genomes)[sel])
    stats.truncated_sizes = [len(o) for o in trunc_obj]
    stats.cross_product_size = float(np.prod([float(n) for n in
                                              stats.truncated_sizes]))

    cur_obj = trunc_obj[0]
    cur_idx = np.arange(len(cur_obj), dtype=np.int64)[:, None]
    for si in range(1, len(fronts)):
        nxt = trunc_obj[si]
        n, k = len(cur_obj), len(nxt)
        stats.pairs_evaluated += n * k
        obj = _combine(cur_obj, nxt, qor_index)
        idx = np.concatenate(
            [
                np.repeat(cur_idx, k, axis=0),
                np.tile(np.arange(k, dtype=np.int64), n)[:, None],
            ],
            axis=1,
        )
        mask = non_dominated_mask(obj)
        cur_obj, cur_idx = obj[mask], idx[mask]
        if max_survivors is not None and len(cur_obj) > max_survivors:
            sel = truncate_front(cur_obj, max_survivors,
                                 sort_index=qor_index
                                 if qor_index is not None else 0)
            cur_obj, cur_idx = cur_obj[sel], cur_idx[sel]

    stats.survivors = len(cur_obj)
    return ComposeResult(
        indices=cur_idx, objectives=cur_obj, stage_genomes=trunc_gen,
        stats=stats,
    )
