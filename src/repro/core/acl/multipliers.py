"""Behavioral models of 8-bit approximate multipliers.

Every function is a vectorized numpy model ``f(a, b) -> p`` where ``a`` and
``b`` are integer arrays holding unsigned 8-bit values (any integer dtype;
values are masked to 8 bits) and ``p`` is the approximate 16-bit product as
int64.  These mirror the behavioral (C++) models of the EvoApprox8b library
used by the paper: the exact netlists are not vendored in this offline
environment, so we generate a structurally equivalent family spanning the
same error-vs-cost spectrum (truncation, partial-product perforation,
broken-array, Mitchell logarithmic, DRUM, Kulkarni-composed).  See
DESIGN.md §8.

All models are deterministic and exhaustively tabulable (256x256), which is
what `repro.core.acl.tables` does.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "mul8_exact",
    "mul8_trunc",
    "mul8_perforated",
    "mul8_broken_array",
    "mul8_mitchell",
    "mul8_drum",
    "mul8_kulkarni",
    "signed_wrap",
]


def _u8(x) -> np.ndarray:
    return np.asarray(x, dtype=np.int64) & 0xFF


def mul8_exact(a, b) -> np.ndarray:
    """Exact unsigned 8x8 -> 16 multiplier."""
    return _u8(a) * _u8(b)


def mul8_trunc(a, b, *, k: int) -> np.ndarray:
    """Operand-truncation multiplier: drop the k LSBs of both operands.

    p = (a >> k) * (b >> k) << 2k.  Classic bitwidth-reduction AC; very
    cheap (a (8-k)x(8-k) core) with a negative-biased error.
    """
    a, b = _u8(a), _u8(b)
    return ((a >> k) * (b >> k)) << (2 * k)


def mul8_perforated(a, b, *, k: int) -> np.ndarray:
    """Partial-product perforation: drop the k least-significant PP rows.

    p = sum_{i=k..7} a_i * (b << i).  Mirrors PPP multipliers (Zervakis et
    al.); saves k rows of the array.
    """
    a, b = _u8(a), _u8(b)
    p = np.zeros_like(a)
    for i in range(k, 8):
        bit = (a >> i) & 1
        p = p + bit * (b << i)
    return p


def mul8_broken_array(a, b, *, k: int) -> np.ndarray:
    """Broken-array multiplier (BAM): omit all carry-save cells below
    column k.  Each partial-product row keeps only the bits at global
    column >= k; the low-order triangle of the array is removed.
    """
    a, b = _u8(a), _u8(b)
    mask = ~np.int64((1 << k) - 1)
    p = np.zeros_like(a)
    for i in range(8):
        bit = (a >> i) & 1
        p = p + (bit * (b << i) & mask)
    return p


def _ilog2(x: np.ndarray) -> np.ndarray:
    """floor(log2(x)) for x >= 1, exact for integers (via frexp)."""
    return np.frexp(x.astype(np.float64))[1].astype(np.int64) - 1


def mul8_mitchell(a, b) -> np.ndarray:
    """Mitchell's logarithmic multiplier (1962), integer realization.

    log2(a) ~= ka + xa/2^ka with xa = a - 2^ka.  The antilog of the summed
    approximate logs gives:
        fa + fb < 1 : p = 2^(ka+kb) + xa*2^kb + xb*2^ka
        fa + fb >= 1: p = 2 * (xa*2^kb + xb*2^ka)
    Zero operands produce zero.
    """
    a, b = _u8(a), _u8(b)
    nz = (a > 0) & (b > 0)
    asafe = np.where(nz, a, 1)
    bsafe = np.where(nz, b, 1)
    ka, kb = _ilog2(asafe), _ilog2(bsafe)
    xa = asafe - (np.int64(1) << ka)
    xb = bsafe - (np.int64(1) << kb)
    cross = xa * (np.int64(1) << kb) + xb * (np.int64(1) << ka)
    base = np.int64(1) << (ka + kb)
    p = np.where(cross < base, base + cross, 2 * cross)
    return np.where(nz, p, 0)


def mul8_drum(a, b, *, k: int) -> np.ndarray:
    """DRUM-k (Hashemi et al., ICCAD'15): dynamic-range unbiased multiplier.

    Keep a k-bit window starting at the leading one of each operand, force
    the window LSB to 1 (unbiasing), multiply the short operands, and shift
    back.  Cited as [11] by the paper.
    """
    a, b = _u8(a), _u8(b)
    nz = (a > 0) & (b > 0)
    asafe = np.where(nz, a, 1)
    bsafe = np.where(nz, b, 1)
    sa = np.maximum(_ilog2(asafe) - (k - 1), 0)
    sb = np.maximum(_ilog2(bsafe) - (k - 1), 0)
    ta = (asafe >> sa) | 1
    tb = (bsafe >> sb) | 1
    p = (ta * tb) << (sa + sb)
    return np.where(nz, p, 0)


_KULKARNI_2X2 = np.array(
    [
        [0, 0, 0, 0],
        [0, 1, 2, 3],
        [0, 2, 4, 6],
        [0, 3, 6, 7],  # 3*3 -> 7 instead of 9: the single approximate cell
    ],
    dtype=np.int64,
)


def _kulkarni_rec(a: np.ndarray, b: np.ndarray, bits: int) -> np.ndarray:
    if bits == 2:
        return _KULKARNI_2X2[a, b]
    h = bits // 2
    mask = (1 << h) - 1
    al, ah = a & mask, a >> h
    bl, bh = b & mask, b >> h
    ll = _kulkarni_rec(al, bl, h)
    lh = _kulkarni_rec(al, bh, h)
    hl = _kulkarni_rec(ah, bl, h)
    hh = _kulkarni_rec(ah, bh, h)
    return ll + ((lh + hl) << h) + (hh << (2 * h))


def mul8_kulkarni(a, b) -> np.ndarray:
    """Kulkarni et al. (VLSID'11) underdesigned multiplier: an 8x8 array
    recursively composed of 2x2 blocks whose single inaccurate entry is
    3*3 -> 7.  Adders in the recomposition tree are exact.
    """
    return _kulkarni_rec(_u8(a), _u8(b), 8)


def signed_wrap(fn):
    """Lift an unsigned 8x8 behavioral model to signed int8 x int8.

    Sign-magnitude wrapper: p = sign(a)*sign(b) * fn(|a|, |b|).  This is
    our mul8s extension (DESIGN.md §8).  |-128| = 128 is passed through to
    the unsigned core unchanged (it fits the 8-bit domain), so the exact
    signed multiplier is bit-exact over the full int8 range.
    """

    def signed(a, b):
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        sgn = np.sign(a) * np.sign(b)
        return sgn * fn(np.abs(a), np.abs(b))

    return signed
