"""NSGA-II as an ask/tell strategy — generation-at-a-time, seed-identical
to the classic ``core.nsga2.nsga2`` loop (which is now a thin driver over
this class).

Round structure:

    round -1   ask -> the initial population (``init`` or a seeded random
               draw); tell -> elitist selection of the first parent set.
    round g    ask -> the offspring of generation g (tournament +
               uniform crossover + random-reset mutation, consuming the
               RNG in exactly the legacy order); tell -> (mu + lambda)
               environmental selection.

With ``cfg.dedup`` the strategy keeps the objective cache itself: ask()
returns only the rows whose objectives it has never seen (first
occurrence order, duplicates within the batch skipped) and tell()
scatters the cached rows back over the full generation — so the
surrogate-call accounting (``n_evaluated``) matches the legacy loop
exactly.
"""

from __future__ import annotations

from dataclasses import asdict
from typing import Dict, List, Optional

import numpy as np

from ..nsga2 import (
    GenerationLog,
    NSGA2Config,
    NSGA2Result,
    _offspring,
    _select_parents,
)
from ..pareto import crowding_distance, fast_non_dominated_sort, non_dominated_mask
from .base import SearchStrategy, decode_array, encode_array

__all__ = ["NSGA2Strategy"]


class NSGA2Strategy(SearchStrategy):
    name = "nsga2"

    def __init__(
        self,
        gene_sizes,
        cfg: Optional[NSGA2Config] = None,
        *,
        init: Optional[np.ndarray] = None,
        keep_history: bool = True,
    ):
        self.gene_sizes = np.asarray(gene_sizes, dtype=np.int64)
        self.cfg = cfg if cfg is not None else NSGA2Config()
        self.keep_history = keep_history
        self._rng = np.random.default_rng(self.cfg.seed)
        # init is drawn lazily at the first ask() so restore() on a fresh
        # instance never wastes (or disturbs) RNG draws
        self._init = None if init is None else np.asarray(init, dtype=np.int64)
        self._cache: Dict[bytes, np.ndarray] = {}
        self._gen = -1                    # -1 = initial-population round
        self._parents: Optional[np.ndarray] = None
        self._pobj: Optional[np.ndarray] = None
        self._pending: Optional[np.ndarray] = None   # full batch awaiting tell
        self._fresh: Optional[np.ndarray] = None     # its uncached rows
        self.n_evaluated = 0
        self.history: List[GenerationLog] = []

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        return self._gen >= self.cfg.n_generations

    def ask(self) -> np.ndarray:
        if self.done:
            raise RuntimeError("strategy is done; ask() has no next batch")
        if self._pending is None:
            if self._gen == -1:
                if self._init is not None:
                    batch = self._init
                else:
                    batch = self._rng.integers(
                        0, self.gene_sizes[None, :],
                        size=(self.cfg.pop_size, len(self.gene_sizes)),
                    )
            else:
                fronts = fast_non_dominated_sort(self._pobj)
                rank = np.zeros(len(self._pobj), dtype=np.int64)
                cd = np.zeros(len(self._pobj))
                for fi, front in enumerate(fronts):
                    rank[front] = fi
                    cd[front] = crowding_distance(self._pobj[front])
                batch = _offspring(
                    self._rng, self._parents, rank, cd,
                    self.gene_sizes, self.cfg.pop_size, self.cfg,
                )
            self._pending = np.asarray(batch, dtype=np.int64)
            self._fresh = self._fresh_rows(self._pending)
        return self._fresh

    def _fresh_rows(self, batch: np.ndarray) -> np.ndarray:
        if not self.cfg.dedup:
            return batch
        rows, seen = [], set()
        for k, g in enumerate(batch):
            key = g.tobytes()
            if key not in self._cache and key not in seen:
                seen.add(key)
                rows.append(k)
        if not rows:
            return batch[:0]
        return batch[np.array(rows)]

    def tell(self, genomes, objectives) -> Optional[GenerationLog]:
        genomes = self._check_tell(self._fresh, genomes)
        objectives = np.asarray(objectives, dtype=np.float64)
        batch = self._pending
        if self.cfg.dedup:
            for g, row in zip(genomes, objectives):
                self._cache[g.tobytes()] = row
            self.n_evaluated += len(genomes)
            full = np.stack([self._cache[g.tobytes()] for g in batch])
        else:
            self.n_evaluated += len(genomes)
            full = objectives
        log = None
        if self._gen == -1:
            self._parents, self._pobj, _ = _select_parents(
                batch, full, self.cfg.n_parents
            )
        else:
            log = GenerationLog(self._gen, batch, full, self.n_evaluated)
            if self.keep_history:
                self.history.append(log)
            allg = np.concatenate([self._parents, batch], axis=0)
            allo = np.concatenate([self._pobj, full], axis=0)
            self._parents, self._pobj, _ = _select_parents(
                allg, allo, self.cfg.n_parents
            )
        self._gen += 1
        self._pending = self._fresh = None
        return log

    def result(self) -> NSGA2Result:
        if self._parents is None:
            raise RuntimeError("no population evaluated yet")
        return NSGA2Result(
            genomes=self._parents,
            objectives=self._pobj,
            front_mask=non_dominated_mask(self._pobj),
            history=self.history,
            n_evaluated=self.n_evaluated,
        )

    def progress(self) -> Dict:
        return {
            "strategy": self.name,
            "generation": int(max(self._gen, 0)),
            "n_generations": int(self.cfg.n_generations),
            "surrogate_evals": int(self.n_evaluated),
            "done": bool(self.done),
        }

    # ------------------------------------------------------------------
    def state(self) -> Dict:
        cache_g = [list(map(int, np.frombuffer(k, dtype=np.int64)))
                   for k in self._cache]
        cache_o = [encode_array(v) for v in self._cache.values()]
        return {
            "name": self.name,
            "cfg": asdict(self.cfg),
            "gene_sizes": encode_array(self.gene_sizes),
            "rng": self._rng.bit_generator.state,
            "gen": int(self._gen),
            "n_evaluated": int(self.n_evaluated),
            "parents": encode_array(self._parents),
            "pobj": encode_array(self._pobj),
            "init": encode_array(self._init),
            "pending": encode_array(self._pending),
            "cache_genomes": cache_g,
            "cache_obj": cache_o,
            "history": [
                {
                    "generation": int(h.generation),
                    "genomes": encode_array(h.genomes),
                    "objectives": encode_array(h.objectives),
                    "n_evaluated": int(h.n_evaluated),
                }
                for h in self.history
            ],
        }

    def restore(self, state: Dict) -> "NSGA2Strategy":
        self.cfg = NSGA2Config(**state["cfg"])
        self.gene_sizes = decode_array(state["gene_sizes"])
        g = len(self.gene_sizes)
        self._rng = np.random.default_rng()
        self._rng.bit_generator.state = state["rng"]
        self._gen = state["gen"]
        self.n_evaluated = state["n_evaluated"]
        self._parents = decode_array(state["parents"], width=g)
        self._pobj = decode_array(state["pobj"], dtype=np.float64)
        self._init = decode_array(state["init"], width=g)
        self._pending = decode_array(state["pending"], width=g)
        self._cache = {
            np.asarray(gg, dtype=np.int64).tobytes():
                np.asarray(oo, dtype=np.float64)
            for gg, oo in zip(state["cache_genomes"], state["cache_obj"])
        }
        self._fresh = (self._fresh_rows(self._pending)
                       if self._pending is not None else None)
        self.history = [
            GenerationLog(
                h["generation"],
                decode_array(h["genomes"], width=g),
                decode_array(h["objectives"], dtype=np.float64),
                h["n_evaluated"],
            )
            for h in state["history"]
        ]
        return self
