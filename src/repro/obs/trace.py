"""Context-propagated span tracing for the DSE service and fleet.

One campaign's labels flow through the campaign worker thread, the
scheduler's batcher, a thread/process/fleet backend, and (for the
fleet) a worker on another HOST — so "where did the last 10 minutes
go?" needs spans whose correlation ids survive every one of those
boundaries.  This module is the zero-dependency flight recorder core:

  * ``span(name, **attrs)`` — a context manager that times a region and
    emits one record; nesting links child to parent via a contextvar.
  * ``context(campaign=..., batch=...)`` — pushes correlation *baggage*
    (campaign/batch/lease/worker ids) that every span started inside it
    carries in its attrs.
  * ``wire_context()`` / ``attach(wire)`` — a plain-dict codec so the
    current trace context can ride existing wire payloads (fleet lease
    responses, process-pool call args) and be re-attached on the far
    side; ``Recorder.ingest`` folds the far side's finished spans back
    into the local ring (workers piggyback them on result payloads,
    exactly like the synth-stat counters already do).

Records land in a bounded in-memory ring plus an optional JSONL sink
(``--trace`` on the service CLI); ``python -m repro.obs.export
--chrome-trace`` turns the sink file into a Perfetto-loadable trace.

Tracing is on by default and costs two clock reads plus a deque append
per span; ``REPRO_OBS=0`` (or ``set_enabled(False)``) turns every
``span``/``context`` into a no-op for overhead benchmarking.
"""

from __future__ import annotations

import contextvars
import json
import os
import threading
import time
import uuid
from collections import deque
from contextlib import contextmanager
from typing import Dict, Iterable, List, Optional

__all__ = [
    "Recorder", "Span", "attach", "context", "current_baggage",
    "enabled", "recorder", "set_enabled", "set_sink", "span",
    "start_span", "wire_context",
]

_BAGGAGE_KEYS = ("campaign", "batch", "lease", "worker", "stage")

_enabled = os.environ.get("REPRO_OBS", "1").lower() not in (
    "0", "false", "off", "no",
)


def enabled() -> bool:
    return _enabled


def set_enabled(on: bool) -> None:
    """Flip tracing globally (the overhead benchmark's obs-off arm)."""
    global _enabled
    _enabled = bool(on)


def _new_id() -> str:
    return uuid.uuid4().hex[:16]


class _Ctx:
    """Immutable trace context: a trace id, the current span id (parent
    of any span started under it) and the correlation baggage."""

    __slots__ = ("trace_id", "span_id", "baggage")

    def __init__(self, trace_id: str, span_id: Optional[str],
                 baggage: Dict[str, str]):
        self.trace_id = trace_id
        self.span_id = span_id
        self.baggage = baggage


_current: contextvars.ContextVar[Optional[_Ctx]] = contextvars.ContextVar(
    "repro_obs_ctx", default=None
)


class Recorder:
    """Bounded ring of finished span records + optional JSONL sink."""

    def __init__(self, ring: int = 4096, sink: Optional[str] = None):
        self._ring: deque = deque(maxlen=int(ring))
        self._lock = threading.Lock()
        self._sink_path: Optional[str] = None
        self._sink_file = None
        self.n_spans = 0
        self.n_ingested = 0
        self.n_dropped = 0  # sink write failures, not ring evictions
        if sink:
            self.set_sink(sink)

    def set_sink(self, path: Optional[str]) -> None:
        with self._lock:
            if self._sink_file is not None:
                try:
                    self._sink_file.close()
                except OSError:
                    pass
                self._sink_file = None
            self._sink_path = path
            if path:
                d = os.path.dirname(os.path.abspath(path))
                os.makedirs(d, exist_ok=True)
                self._sink_file = open(path, "a", encoding="utf-8")

    @property
    def sink_path(self) -> Optional[str]:
        return self._sink_path

    def emit(self, rec: Dict) -> None:
        with self._lock:
            self._ring.append(rec)
            self.n_spans += 1
            if self._sink_file is not None:
                try:
                    self._sink_file.write(
                        json.dumps(rec, separators=(",", ":")) + "\n"
                    )
                    self._sink_file.flush()
                except (OSError, ValueError):
                    self.n_dropped += 1

    def ingest(self, recs: Iterable[Dict]) -> None:
        """Fold spans recorded elsewhere (worker process / fleet host)
        into this recorder — they arrive finished, piggybacked on result
        payloads."""
        for rec in recs:
            if isinstance(rec, dict) and "name" in rec:
                self.emit(rec)
                with self._lock:
                    self.n_ingested += 1

    def snapshot(self) -> List[Dict]:
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def stats(self) -> Dict:
        with self._lock:
            return {
                "spans": self.n_spans,
                "ingested": self.n_ingested,
                "ring": len(self._ring),
                "sink": self._sink_path,
                "sink_drops": self.n_dropped,
            }

    def close(self) -> None:
        self.set_sink(None)


_recorder = Recorder()


def recorder() -> Recorder:
    return _recorder


def set_sink(path: Optional[str]) -> None:
    _recorder.set_sink(path)


def current_baggage() -> Dict[str, str]:
    ctx = _current.get()
    return dict(ctx.baggage) if ctx is not None else {}


@contextmanager
def context(**baggage):
    """Push correlation baggage (and mint a trace id if none is live).
    ``trace_id=`` pins the trace id — campaigns pass their campaign id
    so every span of a campaign shares one trace."""
    if not _enabled:
        yield
        return
    trace_id = baggage.pop("trace_id", None)
    parent = _current.get()
    merged = dict(parent.baggage) if parent is not None else {}
    merged.update({k: str(v) for k, v in baggage.items() if v is not None})
    ctx = _Ctx(
        trace_id or (parent.trace_id if parent is not None else _new_id()),
        parent.span_id if parent is not None else None,
        merged,
    )
    token = _current.set(ctx)
    try:
        yield
    finally:
        _current.reset(token)


class Span:
    """A started span; ``end()`` emits it.  Returned by ``start_span``
    for lifecycles that cross threads (fleet leases: granted on the
    protocol thread, ended by a result post, heartbeat expiry, or the
    in-process reclaim)."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "attrs",
                 "_t0", "_clk", "_rec", "_done")

    def __init__(self, name: str, ctx: Optional[_Ctx], attrs: Dict,
                 rec: Recorder):
        self.name = name
        self.trace_id = ctx.trace_id if ctx is not None else _new_id()
        self.span_id = _new_id()
        self.parent_id = ctx.span_id if ctx is not None else None
        self.attrs = dict(ctx.baggage) if ctx is not None else {}
        self.attrs.update(attrs)
        self._t0 = time.time()
        self._clk = time.perf_counter()
        self._rec = rec
        self._done = False

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def end(self, **attrs) -> None:
        if self._done:
            return
        self._done = True
        if attrs:
            self.attrs.update(attrs)
        self._rec.emit({
            "name": self.name,
            "trace": self.trace_id,
            "span": self.span_id,
            "parent": self.parent_id,
            "t0": round(self._t0, 6),
            "dur": round(time.perf_counter() - self._clk, 6),
            "pid": os.getpid(),
            "tid": threading.get_ident() & 0xFFFFFFFF,
            "attrs": {k: v for k, v in self.attrs.items() if v is not None},
        })


class _NullSpan:
    __slots__ = ()

    def set(self, **attrs) -> None:
        pass

    def end(self, **attrs) -> None:
        pass


_NULL = _NullSpan()


def start_span(name: str, **attrs) -> Span:
    """Start a span WITHOUT making it the ambient parent — for
    lifecycles whose end happens on another thread."""
    if not _enabled:
        return _NULL
    return Span(name, _current.get(), attrs, _recorder)


@contextmanager
def span(name: str, **attrs):
    """Time a region; nested spans parent to it via the contextvar."""
    if not _enabled:
        yield _NULL
        return
    s = Span(name, _current.get(), attrs, _recorder)
    token = _current.set(_Ctx(s.trace_id, s.span_id, dict(s.attrs)))
    try:
        yield s
    finally:
        _current.reset(token)
        s.end()


# ----------------------------------------------------------------------
# wire codec: trace context over existing payloads


def wire_context() -> Optional[Dict]:
    """The current context as a plain JSON-safe dict, or None.  Rides
    fleet lease responses and process-pool call args."""
    if not _enabled:
        return None
    ctx = _current.get()
    if ctx is None:
        return None
    out: Dict = {"trace": ctx.trace_id}
    if ctx.span_id:
        out["span"] = ctx.span_id
    bag = {k: v for k, v in ctx.baggage.items() if k in _BAGGAGE_KEYS}
    if bag:
        out["baggage"] = bag
    return out


@contextmanager
def attach(wire: Optional[Dict], **extra_baggage):
    """Adopt a remote trace context (the far side of ``wire_context``).
    A None/garbage wire still pushes ``extra_baggage`` so worker-local
    spans stay labeled."""
    if not _enabled:
        yield
        return
    wire = wire if isinstance(wire, dict) else {}
    bag = wire.get("baggage")
    merged = dict(bag) if isinstance(bag, dict) else {}
    merged.update(
        {k: str(v) for k, v in extra_baggage.items() if v is not None}
    )
    trace_id = wire.get("trace")
    ctx = _Ctx(
        str(trace_id) if trace_id else _new_id(),
        str(wire["span"]) if wire.get("span") else None,
        merged,
    )
    token = _current.set(ctx)
    try:
        yield
    finally:
        _current.reset(token)
