"""Tree-family surrogates: CART, Random Forest, Extra-Trees, Gradient
Boosting.  Random Forest is the paper's production QoR estimator (Fig. 6).

The CART core is a vectorized variance-reduction regression tree; at the
paper's scale (n~1000, d~10-60) exhaustive split search is cheap.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from .base import Model

__all__ = ["CART", "RandomForest", "ExtraTrees", "GradientBoosting"]


@dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: Optional["_Node"] = None
    right: Optional["_Node"] = None
    value: float = 0.0

    @property
    def is_leaf(self) -> bool:
        return self.feature < 0


def _best_split(X, y, feat_idx, min_leaf):
    """Exhaustive best (feature, threshold) by SSE reduction."""
    n = len(y)
    best = (None, None, 0.0)  # feature, threshold, gain
    base = ((y - y.mean()) ** 2).sum()
    for j in feat_idx:
        order = np.argsort(X[:, j], kind="stable")
        xs, ys = X[order, j], y[order]
        csum = np.cumsum(ys)
        csq = np.cumsum(ys**2)
        tot, tot2 = csum[-1], csq[-1]
        k = np.arange(1, n)
        # valid split positions: leaves >= min_leaf and distinct x
        valid = (k >= min_leaf) & (k <= n - min_leaf) & (xs[1:] != xs[:-1])
        if not valid.any():
            continue
        lsum, lsq = csum[:-1], csq[:-1]
        rsum, rsq = tot - lsum, tot2 - lsq
        sse = (lsq - lsum**2 / k) + (rsq - rsum**2 / (n - k))
        sse = np.where(valid, sse, np.inf)
        kbest = int(np.argmin(sse))
        gain = base - sse[kbest]
        if np.isfinite(sse[kbest]) and gain > best[2]:
            thr = 0.5 * (xs[kbest] + xs[kbest + 1])
            best = (j, thr, gain)
    return best


def _random_split(X, y, feat_idx, min_leaf, rng):
    """Extra-Trees style: one uniform-random threshold per candidate
    feature, pick the best of those."""
    best = (None, None, 0.0)
    base = ((y - y.mean()) ** 2).sum()
    for j in feat_idx:
        lo, hi = X[:, j].min(), X[:, j].max()
        if lo == hi:
            continue
        thr = rng.uniform(lo, hi)
        mask = X[:, j] <= thr
        nl = int(mask.sum())
        if nl < min_leaf or len(y) - nl < min_leaf:
            continue
        yl, yr = y[mask], y[~mask]
        sse = ((yl - yl.mean()) ** 2).sum() + ((yr - yr.mean()) ** 2).sum()
        gain = base - sse
        if gain > best[2]:
            best = (j, thr, gain)
    return best


class CART(Model):
    standardize_x = False
    standardize_y = False

    def __init__(
        self,
        max_depth: int = 12,
        min_leaf: int = 2,
        max_features: Optional[float] = None,  # fraction of features per split
        random_splits: bool = False,
        seed: int = 0,
    ):
        super().__init__(seed)
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.max_features = max_features
        self.random_splits = random_splits

    def _grow(self, X, y, depth, rng):
        node = _Node(value=float(y.mean()))
        if depth >= self.max_depth or len(y) < 2 * self.min_leaf or y.std() == 0:
            return node
        d = X.shape[1]
        if self.max_features is not None:
            k = max(1, int(round(self.max_features * d)))
            feat_idx = rng.choice(d, size=k, replace=False)
        else:
            feat_idx = np.arange(d)
        if self.random_splits:
            j, thr, gain = _random_split(X, y, feat_idx, self.min_leaf, rng)
        else:
            j, thr, gain = _best_split(X, y, feat_idx, self.min_leaf)
        # relative gain threshold: an absolute epsilon silently refuses to
        # split small-magnitude targets (e.g. energies ~1e-7 J), leaving a
        # constant predictor
        base = ((y - y.mean()) ** 2).sum()
        if j is None or gain <= 1e-9 * max(base, 1e-300):
            return node
        mask = X[:, j] <= thr
        node.feature, node.threshold = int(j), float(thr)
        node.left = self._grow(X[mask], y[mask], depth + 1, rng)
        node.right = self._grow(X[~mask], y[~mask], depth + 1, rng)
        return node

    def _fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        self.root = self._grow(X, y, 0, rng)

    def _predict(self, X):
        out = np.empty(X.shape[0])
        # iterative batched traversal
        stack = [(self.root, np.arange(X.shape[0]))]
        while stack:
            node, idx = stack.pop()
            if node.is_leaf or not idx.size:
                out[idx] = node.value
                continue
            mask = X[idx, node.feature] <= node.threshold
            stack.append((node.left, idx[mask]))
            stack.append((node.right, idx[~mask]))
        return out


class RandomForest(Model):
    standardize_x = False
    standardize_y = False

    def __init__(
        self,
        n_trees: int = 50,
        max_depth: int = 12,
        min_leaf: int = 2,
        max_features: float = 0.7,
        seed: int = 0,
    ):
        super().__init__(seed)
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.max_features = max_features

    _tree_cls = CART
    _random_splits = False

    def _fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        n = X.shape[0]
        self.trees = []
        for t in range(self.n_trees):
            idx = rng.integers(0, n, size=n)  # bootstrap
            tree = self._tree_cls(
                max_depth=self.max_depth,
                min_leaf=self.min_leaf,
                max_features=self.max_features,
                random_splits=self._random_splits,
                seed=int(rng.integers(0, 2**31)),
            )
            tree._fit(X[idx], y[idx])
            self.trees.append(tree)

    def _predict(self, X):
        return np.mean([t._predict(X) for t in self.trees], axis=0)


class ExtraTrees(RandomForest):
    _random_splits = True

    def _fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        self.trees = []
        for t in range(self.n_trees):  # no bootstrap (classic ET)
            tree = CART(
                max_depth=self.max_depth,
                min_leaf=self.min_leaf,
                max_features=self.max_features,
                random_splits=True,
                seed=int(rng.integers(0, 2**31)),
            )
            tree._fit(X, y)
            self.trees.append(tree)


class GradientBoosting(Model):
    standardize_x = False
    standardize_y = True

    def __init__(
        self,
        n_stages: int = 100,
        lr: float = 0.1,
        max_depth: int = 3,
        min_leaf: int = 3,
        subsample: float = 1.0,
        seed: int = 0,
    ):
        super().__init__(seed)
        self.n_stages = n_stages
        self.lr = lr
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.subsample = subsample

    def _fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        n = X.shape[0]
        self.base = float(y.mean())
        pred = np.full(n, self.base)
        self.stages = []
        for _ in range(self.n_stages):
            resid = y - pred
            if self.subsample < 1.0:
                idx = rng.choice(n, size=max(1, int(self.subsample * n)), replace=False)
            else:
                idx = np.arange(n)
            tree = CART(max_depth=self.max_depth, min_leaf=self.min_leaf,
                        seed=int(rng.integers(0, 2**31)))
            tree._fit(X[idx], resid[idx])
            pred = pred + self.lr * tree._predict(X)
            self.stages.append(tree)

    def _predict(self, X):
        out = np.full(X.shape[0], self.base)
        for tree in self.stages:
            out = out + self.lr * tree._predict(X)
        return out
