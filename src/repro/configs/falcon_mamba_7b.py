"""falcon-mamba-7b [ssm] — pure Mamba-1, attention-free
[arXiv:2410.05355]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    notes="n_heads/n_kv_heads are nominal; no attention layers exist.",
)
