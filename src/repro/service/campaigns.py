"""Campaign manager + warm surrogate registry.

A *campaign* is one three-stage DSE owned by the service.  It is NOT a
blocking ``run_dse`` call on a dedicated thread: the manager steps
``core.strategies.Campaign`` state machines cooperatively — one executor
task per tick (a label request, one ask/tell strategy round, or one
label delivery) — so N campaigns multiplex over a small worker pool and
a campaign whose ground truth is in flight holds no thread at all.
Every tick boundary snapshots the campaign state, which is what backs
``cancel``/``resume`` (``POST /campaigns/<id>/resume`` continues a
killed campaign, cross-process when ``snapshot_path`` is set).

Ground-truth labeling runs through the shared ``EvalScheduler`` (store
reuse + in-flight dedup + coalesced batches) and surrogate fits go
through the ``SurrogateRegistry`` (warm fitted models keyed by
``(eval context, pipeline, objective, model, seed)``).

Warm-surrogate modes (``CampaignSpec.warm_surrogates``):

  * ``"reuse"`` (default) — an exact match on the training-set digest
    returns the already-fitted model with NO refit; results stay
    bit-identical to a cold run (same data -> same fit).
  * ``"accumulate"`` — a key match with NEW data refits on the union of
    everything the registry has seen for that key (incremental refit
    instead of a from-scratch retrain on a larger, redundant sample).
    Deliberately trades bit-reproducibility for surrogate quality.
  * ``"off"`` — always fit fresh.
"""

from __future__ import annotations

import hashlib
import threading
import time
import uuid
from concurrent.futures import ThreadPoolExecutor
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import obs
from ..core.dse import DSEConfig, DSEResult
from ..core.nsga2 import NSGA2Config
from ..core.pareto import non_dominated_mask
from ..core.surrogates import make
from .scheduler import EvalScheduler
from .store import LABEL_KEYS, EvalContext, InMemoryLabelStore, LabelStore

_log = obs.get_logger("repro.service.campaigns")

__all__ = [
    "CampaignSpec",
    "HierarchicalSpec",
    "CampaignManager",
    "SurrogateRegistry",
    "make_accelerator",
    "register_accelerator",
    "unregister_accelerator",
]

# extension point: custom accelerator factories by name (used by
# repro.hierarchy to make ad-hoc pipelines resolvable by the campaign
# workers; also handy for tests)
_REGISTRY: Dict[str, callable] = {}


def register_accelerator(name: str, factory) -> None:
    """Register a zero-arg factory so ``make_accelerator(name)`` (and
    hence campaign specs) can resolve a custom accelerator.  The entry
    lives for the process (run_hierarchical relies on the name staying
    resolvable for its stage campaigns); reclaim retired names with
    ``unregister_accelerator``."""
    _REGISTRY[name] = factory


def unregister_accelerator(name: str) -> bool:
    """Drop a registered factory (no-op on unknown names).  Only do this
    once no in-flight campaign still resolves the name."""
    return _REGISTRY.pop(name, None) is not None


def make_accelerator(name: str, *, builtin_only: bool = False):
    """Accelerator factory for service requests.

    ``mcm1``..``mcm4`` (HEVC DCT rows), ``hevc_dct4x4``, ``gaussian3x3``,
    ``smoothed_dct`` (the staged Gaussian->DCT pipeline),
    ``<pipeline>/stage<i>`` (one stage of a staged pipeline, QoR in situ)
    and ``lm:<arch>`` (e.g. ``lm:granite-8b``).  Names registered via
    ``register_accelerator`` take precedence unless ``builtin_only``
    (the process-pool labeler resolves with the registry bypassed: a
    spawned worker has no registry, so the parent must mirror what the
    worker would build)."""
    if not builtin_only and name in _REGISTRY:
        return _REGISTRY[name]()
    if "/stage" in name:
        base, _, idx = name.rpartition("/stage")
        pipe = make_accelerator(base, builtin_only=builtin_only)
        if not hasattr(pipe, "stage_views"):
            raise ValueError(f"{base!r} is not a staged pipeline")
        views = pipe.stage_views()
        if not idx.isdigit() or int(idx) >= len(views):
            raise ValueError(
                f"unknown stage {name!r}: {base!r} has stages "
                f"0..{len(views) - 1}"
            )
        return views[int(idx)]
    from ..accel import GaussianFilter, HEVCDct, MCMAccelerator

    if name.startswith("mcm"):
        try:
            row = int(name[3:]) - 1
        except ValueError:
            raise ValueError(f"unknown accelerator {name!r}") from None
        if not 0 <= row < 4:
            raise ValueError(f"unknown MCM accelerator {name!r}")
        return MCMAccelerator(row)
    if name == "hevc_dct4x4":
        return HEVCDct()
    if name == "gaussian3x3":
        return GaussianFilter()
    if name == "smoothed_dct":
        from ..accel.smoothed_dct import SmoothedDct

        return SmoothedDct()
    if name.startswith("lm:"):
        from ..accel.lm import LMAccelerator
        from ..configs import get_config

        try:
            config = get_config(name[3:])
        except KeyError as exc:
            # ValueError is the factory's contract (-> HTTP 400)
            raise ValueError(f"unknown accelerator {name!r}: {exc}") from exc
        return LMAccelerator(config)
    raise ValueError(f"unknown accelerator {name!r}")


@dataclass(frozen=True)
class CampaignSpec:
    """A serializable DSE request (what the HTTP API accepts)."""

    accel: str = "mcm2"
    pipeline: str = "D"
    qor_model: str = "random_forest"
    hw_model: str = "bayesian_ridge"
    strategy: str = "nsga2"         # explorer (core.strategies registry)
    objectives: Tuple[str, ...] = ("qor", "energy")
    n_train: int = 80
    n_qor_samples: int = 4
    rank_genes: bool = False
    warm_start: bool = True
    pop_size: int = 48
    n_parents: int = 16
    n_generations: int = 10
    seed: int = 0
    warm_surrogates: str = "reuse"   # "reuse" | "accumulate" | "off"

    def __post_init__(self):
        if self.warm_surrogates not in ("reuse", "accumulate", "off"):
            raise ValueError(
                f"warm_surrogates must be 'reuse', 'accumulate' or 'off', "
                f"got {self.warm_surrogates!r}"
            )

    def validate(self) -> None:
        """Submit-time validation: reject unknown accelerators and
        malformed sizes with a ValueError (HTTP 400) instead of letting
        the campaign fail asynchronously in a worker thread."""
        _validate_sizes(self)
        from ..core.strategies import available_strategies

        if self.strategy not in available_strategies():
            raise ValueError(
                f"unknown strategy {self.strategy!r}; known: "
                f"{available_strategies()}"
            )
        make_accelerator(self.accel)  # raises ValueError if unknown

    def dse_config(self) -> DSEConfig:
        return DSEConfig(
            pipeline=self.pipeline,
            hw_model=self.hw_model,
            qor_model=self.qor_model,
            strategy=self.strategy,
            objectives=tuple(self.objectives),
            n_train=self.n_train,
            n_qor_samples=self.n_qor_samples,
            rank_genes=self.rank_genes,
            warm_start=self.warm_start,
            nsga=NSGA2Config(
                pop_size=self.pop_size,
                n_parents=self.n_parents,
                n_generations=self.n_generations,
                seed=self.seed,
            ),
            seed=self.seed,
        )

    @classmethod
    def from_dict(cls, d: Dict) -> "CampaignSpec":
        d = dict(d)
        d.pop("hierarchical", None)   # an explicit false is still valid
        if "objectives" in d:
            d["objectives"] = tuple(d["objectives"])
        return cls(**d)


def _validate_sizes(spec) -> None:
    """Shared size/objective sanity checks for campaign-like specs."""
    from .store import LABEL_KEYS

    for name in ("n_train", "n_qor_samples", "pop_size", "n_parents"):
        v = getattr(spec, name)
        if not isinstance(v, int) or v <= 0:
            raise ValueError(f"{name} must be a positive integer, got {v!r}")
    if not isinstance(spec.n_generations, int) or spec.n_generations < 0:
        raise ValueError(
            f"n_generations must be a non-negative integer, "
            f"got {spec.n_generations!r}"
        )
    if spec.n_parents > spec.pop_size:
        raise ValueError(
            f"n_parents ({spec.n_parents}) cannot exceed pop_size "
            f"({spec.pop_size})"
        )
    objs = tuple(spec.objectives)
    if not objs:
        raise ValueError("objectives cannot be empty")
    unknown = sorted(set(objs) - set(LABEL_KEYS))
    if unknown:
        raise ValueError(
            f"unknown objectives {unknown}; known: {sorted(LABEL_KEYS)}"
        )


@dataclass(frozen=True)
class HierarchicalSpec:
    """A serializable hierarchical-search request: per-stage campaign
    budget + composition knobs over a staged pipeline accelerator
    (``POST /campaigns`` with ``{"hierarchical": true, ...}``)."""

    accel: str = "smoothed_dct"
    stages: Tuple[Dict, ...] = ()     # optional per-stage spec overrides
    pipeline: str = "D"
    qor_model: str = "random_forest"
    hw_model: str = "bayesian_ridge"
    strategy: str = "nsga2"           # explorer for every stage campaign
    objectives: Tuple[str, ...] = ("qor", "energy")
    n_train: int = 48
    n_qor_samples: int = 2
    rank_genes: bool = False
    warm_start: bool = True
    pop_size: int = 24
    n_parents: int = 12
    n_generations: int = 6
    seed: int = 0
    k_per_stage: Optional[int] = 12
    max_candidates: int = 64

    def validate(self) -> None:
        _validate_sizes(self)
        if self.max_candidates <= 0:
            raise ValueError("max_candidates must be positive")
        if self.k_per_stage is not None and self.k_per_stage <= 0:
            raise ValueError("k_per_stage must be positive or null")
        accel = make_accelerator(self.accel)
        if not hasattr(accel, "stage_views"):
            raise ValueError(
                f"{self.accel!r} is not a staged pipeline (hierarchical "
                f"search needs stages)"
            )
        n_stages = len(accel.stages)
        if self.stages and len(self.stages) != n_stages:
            raise ValueError(
                f"stages has {len(self.stages)} override entries; "
                f"{self.accel!r} has {n_stages} stages"
            )
        # validate the overridden per-stage specs too, so a bad override
        # is a 400 at submit, not an async failure in the hier worker
        cfg = self.hier_config()
        for i in range(n_stages):
            try:
                spec = cfg.stage_spec(
                    f"{self.accel}/stage{i}",
                    self.stages[i] if self.stages else None,
                )
            except TypeError as exc:
                raise ValueError(f"bad stage {i} override: {exc}") from exc
            try:
                spec.validate()
            except ValueError as exc:
                raise ValueError(f"bad stage {i} spec: {exc}") from exc

    def hier_config(self):
        # field-name intersection, so a knob added to both dataclasses
        # flows through without a hand-maintained copy list
        import dataclasses

        from ..hierarchy.search import HierarchicalConfig

        names = {f.name for f in dataclasses.fields(HierarchicalConfig)}
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name in names}
        d["objectives"] = tuple(self.objectives)
        return HierarchicalConfig(**d)

    @classmethod
    def from_dict(cls, d: Dict) -> "HierarchicalSpec":
        d = dict(d)
        d.pop("hierarchical", None)
        if "objectives" in d:
            d["objectives"] = tuple(d["objectives"])
        if "stages" in d:
            stages = d["stages"]
            if not isinstance(stages, (list, tuple)) or not all(
                isinstance(s, dict) for s in stages
            ):
                raise ValueError("stages must be a list of override objects")
            d["stages"] = tuple(dict(s) for s in stages)
        return cls(**d)


class SurrogateRegistry:
    """Fitted surrogates kept warm across campaigns."""

    def __init__(self, max_models: int = 64):
        self._lock = threading.Lock()
        self._models: Dict[Tuple, Dict] = {}   # key -> {digest, model, ...}
        self._data: Dict[Tuple, Dict[bytes, Tuple]] = {}  # key -> row pool
        # service is long-lived: bound retention (dict order = insertion
        # order, so eviction drops the oldest key and its row pool)
        self.max_models = int(max_models)
        self.fits = 0
        self.refits = 0
        self.reuse_hits = 0

    def _store_model(self, key: Tuple, ent: Dict) -> None:
        """Insert under the lock, evicting the oldest beyond max_models."""
        self._models.pop(key, None)  # re-insert moves key to newest
        self._models[key] = ent
        while len(self._models) > self.max_models:
            oldest = next(iter(self._models))
            del self._models[oldest]
            self._data.pop(oldest, None)

    @staticmethod
    def _digest(X: np.ndarray, y: np.ndarray) -> str:
        h = hashlib.sha256(np.ascontiguousarray(X).tobytes())
        h.update(np.ascontiguousarray(y).tobytes())
        return h.hexdigest()[:24]

    def provider(self, ctx_fp: str, spec: CampaignSpec):
        """A ``surrogate_provider(obj, model_name, X, y)`` for run_dse,
        bound to one evaluation context + campaign settings."""
        mode = spec.warm_surrogates

        def provide(obj: str, model_name: str, X: np.ndarray, y: np.ndarray):
            if mode == "off":
                with self._lock:
                    self.fits += 1
                return make(model_name, seed=spec.seed).fit(X, y)
            key = (ctx_fp, spec.pipeline, obj, model_name, spec.seed)
            digest = self._digest(X, y)
            with self._lock:
                ent = self._models.get(key)
                if ent is not None and ent["digest"] == digest:
                    self.reuse_hits += 1
                    self._store_model(key, ent)  # refresh LRU recency
                    return ent["model"]
            if mode == "accumulate":
                with self._lock:
                    pool = self._data.setdefault(key, {})
                    for xi, yi in zip(X, y):
                        # key rows by (x, y) so distinct genomes mapping
                        # to one feature vector but different ground
                        # truth both survive instead of last-write-wins
                        rk = (np.ascontiguousarray(xi).tobytes(),
                              float(yi).hex())
                        pool[rk] = (xi, yi)
                    rows = list(pool.values())
                Xa = np.stack([r[0] for r in rows])
                ya = np.array([r[1] for r in rows])
                model = make(model_name, seed=spec.seed).fit(Xa, ya)
                with self._lock:
                    refit = key in self._models
                    self.refits += int(refit)
                    self.fits += int(not refit)
                    self._store_model(key, {"digest": digest, "model": model,
                                            "rows": len(rows)})
                return model
            # mode == "reuse": fit on exactly this data, cache by digest
            model = make(model_name, seed=spec.seed).fit(X, y)
            with self._lock:
                self.fits += 1
                self._store_model(key, {"digest": digest, "model": model,
                                        "rows": len(X)})
            return model

        return provide

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "models": len(self._models),
                "fits": self.fits,
                "refits": self.refits,
                "reuse_hits": self.reuse_hits,
            }


@dataclass
class _Campaign:
    id: str
    spec: object                     # CampaignSpec | HierarchicalSpec
    kind: str = "dse"                # dse | hierarchical
    state: str = "queued"            # queued | running | done | failed | cancelled
    submitted_at: float = field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    error: Optional[str] = None
    result: Optional[DSEResult] = None
    done_evt: threading.Event = field(default_factory=threading.Event)
    # cooperative-stepping machinery (kind == "dse" only)
    driver: Optional[object] = None          # core.strategies.Campaign
    ctx: Optional[EvalContext] = None
    inbox: Optional[Tuple] = None            # (LabelRequest, labels) to deliver
    restore_state: Optional[Dict] = None     # snapshot to install on build
    cancel_requested: bool = False
    steps: int = 0


class _CompactResult:
    """What remains of a campaign result after retention compaction: the
    Pareto front and summary stats; the heavy train/search arrays
    (train genomes/labels, full NSGA-II population, stage fronts and
    candidate labels for hierarchical jobs) are dropped."""

    def __init__(self, res):
        self.accel_name = res.accel_name
        self.config = res.config
        self.val_pcc = res.val_pcc
        self.timings = res.timings
        self.front_genomes = np.array(res.front_genomes)
        self.front_objectives = np.array(res.front_objectives)
        self.true_objectives = self.front_objectives
        self.front_mask = np.ones(len(self.front_genomes), dtype=bool)
        self.n_designs = int(len(res.true_objectives))
        # hierarchical summary fields (status() reads them off the result)
        for attr in ("stage_campaign_ids", "ground_truth_calls",
                     "flat_space_size", "max_concurrent_stages"):
            if hasattr(res, attr):
                setattr(self, attr, getattr(res, attr))


class CampaignManager:
    """Owns the store, the scheduler, the surrogate registry and a pool
    of campaign-runner threads.  The HTTP front end (``api.py``) is a
    thin shell over this object; tests drive it in-process."""

    def __init__(
        self,
        store: Optional[LabelStore] = None,
        *,
        scheduler: Optional[EvalScheduler] = None,
        eval_workers: int = 2,
        eval_backend: str = "thread",
        process_workers: Optional[int] = None,
        chunk_size: Optional[int] = None,
        fleet_fallback: str = "thread",
        lease_ttl_s: float = 30.0,
        heartbeat_ttl_s: float = 15.0,
        fleet_chunk: Optional[int] = None,
        campaign_workers: int = 2,
        hier_workers: int = 1,
        max_batch: int = 32,
        max_wait_s: float = 0.02,
        keep_results: int = 128,
        keep_campaigns: int = 2048,
        snapshot_every: int = 1,
        snapshot_path: Optional[str] = None,
        synth_cache: Optional[object] = None,
        serving: Optional[Dict] = None,
    ):
        self.store = store if store is not None else InMemoryLabelStore()
        # persistent structural compile cache (core.features.synth): a
        # path opens the segmented compile cache shared by every campaign
        # AND (by path) every process-pool labeler worker; a SynthCache
        # object is used as-is; None keeps the process-default in-memory
        # sharing
        self._owns_synth_cache = isinstance(synth_cache, str)
        if self._owns_synth_cache:
            from ..core.features.synth import open_synth_cache

            self.synth_cache = open_synth_cache(synth_cache, migrate=True)
        else:
            self.synth_cache = synth_cache
        self.scheduler = scheduler or EvalScheduler(
            self.store, n_workers=eval_workers,
            max_batch=max_batch, max_wait_s=max_wait_s,
            backend=eval_backend, process_workers=process_workers,
            chunk_size=chunk_size,
            fleet_fallback=fleet_fallback,
            lease_ttl_s=lease_ttl_s, heartbeat_ttl_s=heartbeat_ttl_s,
            fleet_chunk=fleet_chunk,
            synth_cache_path=getattr(self.synth_cache, "path", None),
        )
        self.registry = SurrogateRegistry()
        # per-campaign search telemetry, sampled at tick boundaries and
        # served by GET /campaigns/<id>/timeline
        self.timeline = obs.Timeline()
        # campaign workers STEP campaigns cooperatively: one executor
        # task is one tick (a label request, one strategy round, or one
        # label delivery), so N campaigns multiplex over few threads and
        # a campaign waiting on ground truth holds no thread at all
        self._pool = ThreadPoolExecutor(
            campaign_workers, thread_name_prefix="campaign"
        )
        # hierarchical jobs wait on campaigns they submit to _pool, so
        # they get their own (small) pool to rule out self-deadlock
        self._hier_pool = ThreadPoolExecutor(
            max(1, hier_workers), thread_name_prefix="hier"
        )
        self._lock = threading.Lock()
        self._campaigns: Dict[str, _Campaign] = {}
        self._seq = 0
        # the service is long-lived: beyond the newest keep_results
        # finished campaigns, results are compacted to their fronts;
        # beyond keep_campaigns, records are dropped entirely
        self.keep_results = int(keep_results)
        self.keep_campaigns = int(keep_campaigns)
        # snapshots: latest per-campaign state at tick boundaries, for
        # POST /campaigns/<id>/resume.  In-memory always; with
        # snapshot_path also appended as JSON lines (last record per id
        # wins on replay), so a campaign killed WITH its process can be
        # resumed by a fresh manager pointed at the same file
        self.snapshot_every = max(1, int(snapshot_every))
        self.snapshot_path = snapshot_path
        self._snapshots: Dict[str, Dict] = {}
        self._snap_lock = threading.Lock()
        self._snap_fh = None
        self._snap_lines = 0
        if snapshot_path:
            self._replay_snapshots(snapshot_path)
        # serving tier: front-update listeners (ServingEngine.attach /
        # ServingHub) fire whenever a campaign completes, so an engine
        # serving an accelerator hot-swaps in the improved front; the
        # hub itself is created lazily on first POST /serve
        self._front_listeners: List = []
        self._serving = None
        self._serving_kw = dict(serving or {})
        self._serving_lock = threading.Lock()

    # ------------------------------------------------------------------
    def _admit(self, spec, kind: str) -> _Campaign:
        """Validate, record and return a new campaign (raises ValueError
        on a bad spec BEFORE any worker thread is involved)."""
        spec.validate()
        # pick up labels other processes appended to a shared store file
        if hasattr(self.store, "refresh"):
            self.store.refresh()
        with self._lock:
            self._seq += 1
            cid = f"c{self._seq:04d}-{uuid.uuid4().hex[:6]}"
            c = _Campaign(id=cid, spec=spec, kind=kind)
            self._campaigns[cid] = c
        return c

    def submit(self, spec: CampaignSpec) -> str:
        c = self._admit(spec, "dse")
        _log.info("campaign %s submitted: accel=%s strategy=%s",
                  c.id, spec.accel, spec.strategy)
        self._enqueue(c)
        return c.id

    def submit_hierarchical(self, spec: HierarchicalSpec) -> str:
        """Run a hierarchical search as a service job.  The job itself
        occupies a dedicated small pool — its per-stage campaigns go
        through the regular campaign pool, so a hierarchical job can
        never deadlock waiting on workers it is itself occupying."""
        c = self._admit(spec, "hierarchical")
        self._hier_pool.submit(self._run_hier, c)
        return c.id

    # ------------------------------------------------------------------
    # cooperative stepping: one executor task == one campaign tick
    # ------------------------------------------------------------------
    def _enqueue(self, c: _Campaign) -> None:
        self._pool.submit(self._step, c)

    def _build_driver(self, c: _Campaign) -> None:
        from ..core.acl.library import default_library
        from ..core.strategies.campaign import Campaign as DseCampaign

        spec = c.spec
        accel = make_accelerator(spec.accel)
        library = default_library()
        c.ctx = EvalContext(
            accel, library,
            rank_genes=spec.rank_genes,
            n_qor_samples=spec.n_qor_samples,
            synth_cache=self.synth_cache,
        )
        provider = self.registry.provider(c.ctx.fingerprint, spec)
        c.driver = DseCampaign(
            accel, library, spec.dse_config(), surrogate_provider=provider,
        )
        if c.restore_state is not None:
            c.driver.restore(c.restore_state)
            c.restore_state = None

    def _step(self, c: _Campaign) -> None:
        """One cooperative tick.  Re-enqueues itself while runnable;
        parks (holding NO thread) while labels are in flight — the
        gather callback re-enqueues on delivery.

        The tick runs under the campaign's trace context (trace id ==
        campaign id), so every span it causes — strategy rounds, label
        batches, synth compiles, fleet leases — correlates back to the
        campaign in the exported trace."""
        try:
            with obs.context(campaign=c.id, trace_id=c.id), \
                    obs.span("campaign.tick", step=c.steps,
                             kind=c.kind) as sp:
                self._tick(c, sp)
        except Exception as exc:  # noqa: BLE001 - campaign isolation
            self._fail(c, exc)

    def _tick(self, c: _Campaign, sp) -> None:
        _log.debug("tick %d state=%s", c.steps, c.state)
        if c.state == "queued":
            c.state = "running"
            if c.started_at is None:
                c.started_at = time.time()
        if c.cancel_requested:
            self._save_snapshot(c)
            c.state = "cancelled"
            c.finished_at = time.time()
            sp.set(action="cancel")
            _log.info("campaign %s cancelled at tick %d", c.id, c.steps)
            c.done_evt.set()
            return
        if c.driver is None:
            self._build_driver(c)
        if c.inbox is not None:
            req, labels = c.inbox
            c.inbox = None
            sp.set(action="deliver", stage=req.stage)
            c.driver.deliver(req, labels)
            self._save_snapshot(c)
        elif not c.driver.done:
            req = c.driver.step()
            if req is not None:
                sp.set(action="request", stage=req.stage,
                       n=int(len(req.genomes)))
                self._sample_timeline(c)
                self._dispatch_labels(c, req)
                return
            sp.set(action="round")
            c.steps += 1
            if c.steps % self.snapshot_every == 0:
                self._save_snapshot(c)
        self._sample_timeline(c)
        if c.driver.done:
            c.result = c.driver.result()
            c.state = "done"
            self._drop_snapshot(c.id)
            c.finished_at = time.time()
            sp.set(done=True)
            _log.info("campaign %s done: %d ticks in %.1fs", c.id,
                      c.steps, c.finished_at - (c.started_at or c.finished_at))
            c.done_evt.set()
            self._evict()
            self._notify_front(c.spec.accel)
        else:
            self._enqueue(c)

    def _sample_timeline(self, c: _Campaign) -> None:
        """One search-telemetry sample at a tick boundary.  Best-effort
        by design: telemetry must never fail a campaign."""
        d = c.driver
        if d is None:
            return
        try:
            fields: Dict = {}
            prog = d.progress()
            fields["stage"] = prog.get("stage")
            fields["labels_requested"] = prog.get("labels_requested", 0)
            if "generation" in prog:
                fields["generation"] = prog["generation"]
            sched = self.scheduler.campaign_stats(c.id)
            if sched:
                fields["labels_served"] = sched.get("labeled", 0)
                fields["store_hits"] = sched.get("store_hits", 0)
                req = sched.get("requests", 0)
                hits = (sched.get("store_hits", 0)
                        + sched.get("inflight_hits", 0))
                fields["label_reuse_rate"] = (hits / req) if req else 0.0
            front = (d.front_estimate()
                     if hasattr(d, "front_estimate") else None)
            self.timeline.sample(c.id, objectives=front, **fields)
        except Exception:  # noqa: BLE001 - telemetry is best-effort
            pass

    def _dispatch_labels(self, c: _Campaign, req) -> None:
        """Fan the request out through the scheduler and park the
        campaign; the last-resolved future re-enqueues it."""
        from .scheduler import gather_futures

        futures = self.scheduler.submit(c.ctx, req.genomes, campaign=c.id)

        def _delivered(recs, exc):
            # runs as a Future done-callback, where raised exceptions are
            # swallowed — every failure must route through _fail or the
            # campaign would park in "running" forever
            try:
                if exc is not None:
                    self._fail(c, exc)
                    return
                labels = {
                    k: np.array([float(r[k]) for r in recs])
                    for k in LABEL_KEYS
                }
                c.inbox = (req, labels)
                self._enqueue(c)
            except Exception as cb_exc:  # noqa: BLE001 - campaign isolation
                self._fail(c, cb_exc)

        gather_futures(futures, _delivered)

    def _fail(self, c: _Campaign, exc: BaseException) -> None:
        c.state = "failed"
        c.error = f"{type(exc).__name__}: {exc}"
        _log.warning("campaign %s failed: %s", c.id, c.error)
        c.finished_at = time.time()
        c.done_evt.set()
        self._evict()

    # ------------------------------------------------------------------
    # cancel / resume
    # ------------------------------------------------------------------
    def cancel(self, cid: str) -> None:
        """Request cancellation; takes effect at the campaign's next
        tick boundary (its snapshot is kept for ``resume``)."""
        c = self._get(cid)
        if c.kind != "dse":
            raise RuntimeError(
                f"campaign {cid} is {c.kind}; only dse campaigns cancel "
                f"(cancel its stage campaigns instead)"
            )
        if c.state in ("done", "failed", "cancelled"):
            raise RuntimeError(f"campaign {cid} already {c.state}")
        c.cancel_requested = True

    def resume(self, cid: str) -> str:
        """Continue a cancelled/failed campaign from its latest snapshot
        (same id).  Unknown ids are looked up in the persistent snapshot
        file, so a campaign killed with its process resumes on a fresh
        manager pointed at the same ``snapshot_path``.  Ground truth the
        campaign re-requests is answered by the label store, so the
        replayed portion is cheap."""
        with self._lock:
            c = self._campaigns.get(cid)
            snap = self._snapshots.get(cid)
        if c is None:
            if snap is None:
                raise KeyError(cid)
            spec = CampaignSpec.from_dict(snap["spec"])
            with self._lock:
                c = _Campaign(id=cid, spec=spec, kind="dse")
                self._campaigns[cid] = c
        else:
            if c.kind != "dse":
                raise RuntimeError(f"campaign {cid} is {c.kind}; "
                                   f"only dse campaigns resume")
            if c.state not in ("cancelled", "failed"):
                raise RuntimeError(
                    f"campaign {cid} is {c.state}; only cancelled/failed "
                    f"campaigns resume"
                )
        c.state = "queued"
        c.error = None
        c.finished_at = None
        c.cancel_requested = False
        c.inbox = None
        c.driver = None          # rebuilt from the snapshot on next tick
        c.restore_state = snap["campaign"] if snap is not None else None
        c.done_evt = threading.Event()
        self._enqueue(c)
        return cid

    # ------------------------------------------------------------------
    # snapshots
    # ------------------------------------------------------------------
    def _append_snap(self, rec: Dict) -> None:
        """Append one snapshot record (called under _snap_lock).  Every
        tick appends the FULL campaign state, so the log is rewritten
        down to one line per live campaign whenever it holds >4x as many
        lines as ids (the JsonlLabelStore compaction idiom) — without
        this, snapshot files would grow quadratically per campaign and
        accumulate across service runs forever."""
        import json
        import os

        if self._snap_fh is None:
            d = os.path.dirname(os.path.abspath(self.snapshot_path))
            os.makedirs(d, exist_ok=True)
            self._snap_fh = open(self.snapshot_path, "a")
        self._snap_fh.write(json.dumps(rec, default=float) + "\n")
        self._snap_fh.flush()
        self._snap_lines += 1
        if self._snap_lines > max(16, 4 * len(self._snapshots)):
            self._snap_fh.close()
            tmp = self.snapshot_path + ".compact.tmp"
            with open(tmp, "w") as f:
                for snap in self._snapshots.values():
                    f.write(json.dumps(snap, default=float) + "\n")
            os.replace(tmp, self.snapshot_path)
            self._snap_fh = open(self.snapshot_path, "a")
            self._snap_lines = len(self._snapshots)

    def _save_snapshot(self, c: _Campaign) -> None:
        if c.driver is None or c.driver.done:
            return
        snap = {
            "id": c.id,
            "kind": c.kind,
            "t": time.time(),
            "spec": {**asdict(c.spec),
                     "objectives": list(c.spec.objectives)},
            "campaign": c.driver.state(),
        }
        with self._snap_lock:
            self._snapshots[c.id] = snap
            if self.snapshot_path:
                self._append_snap(snap)

    def _drop_snapshot(self, cid: str) -> None:
        with self._snap_lock:
            dropped = self._snapshots.pop(cid, None) is not None
            if dropped and self.snapshot_path:
                # tombstone so a later replay does not resurrect a
                # finished campaign as resumable
                self._append_snap({"id": cid, "done": True})

    def _replay_snapshots(self, path: str) -> None:
        import json
        import os

        if not os.path.exists(path):
            return
        with open(path) as f:
            for line in f:
                if not line.endswith("\n"):
                    break              # torn tail from a killed writer
                self._snap_lines += 1
                try:
                    snap = json.loads(line)
                    if snap.get("done"):
                        self._snapshots.pop(snap["id"], None)
                    else:
                        self._snapshots[snap["id"]] = snap   # last wins
                except (json.JSONDecodeError, KeyError):
                    continue

    def snapshot_ids(self) -> List[str]:
        """Campaign ids with a resumable snapshot."""
        with self._snap_lock:
            return sorted(self._snapshots)

    def _run_hier(self, c: _Campaign) -> None:
        c.state = "running"
        c.started_at = time.time()
        try:
            from ..hierarchy.search import run_hierarchical

            spec = c.spec
            pipeline = make_accelerator(spec.accel)
            # the job span covers the whole hierarchical run; its stage
            # campaigns tick under their OWN trace ids (one trace per
            # campaign), linked back here by the parent attribute
            with obs.context(campaign=c.id, trace_id=c.id), \
                    obs.span("campaign.hier", accel=spec.accel):
                c.result = run_hierarchical(
                    pipeline, cfg=spec.hier_config(), manager=self,
                    stage_overrides=spec.stages or None,
                )
            c.state = "done"
        except Exception as exc:  # noqa: BLE001 - campaign isolation
            c.state = "failed"
            c.error = f"{type(exc).__name__}: {exc}"
        finally:
            c.finished_at = time.time()
            c.done_evt.set()
            self._evict()
            if c.state == "done":
                self._notify_front(c.spec.accel)

    def _evict(self) -> None:
        """Bound retention: compact old finished campaigns to their
        fronts, drop the very oldest records (and their scheduler
        accounting) entirely."""
        dropped = []
        with self._lock:
            finished = sorted(
                (c for c in self._campaigns.values()
                 if c.state in ("done", "failed") and c.finished_at),
                key=lambda c: c.finished_at,
            )
            n_drop = max(0, len(finished) - self.keep_campaigns)
            for c in finished[:n_drop]:
                del self._campaigns[c.id]
                dropped.append(c.id)
            for c in finished[n_drop:max(0, len(finished)
                                         - self.keep_results)]:
                if c.result is not None and not isinstance(c.result,
                                                           _CompactResult):
                    c.result = _CompactResult(c.result)
        for cid in dropped:
            self.scheduler.forget_campaign(cid)
            self.timeline.forget(cid)

    # ------------------------------------------------------------------
    def _get(self, cid: str) -> _Campaign:
        with self._lock:
            if cid not in self._campaigns:
                raise KeyError(cid)
            return self._campaigns[cid]

    def wait(self, cid: str, timeout: Optional[float] = None) -> str:
        c = self._get(cid)
        c.done_evt.wait(timeout)
        return c.state

    def status(self, cid: str) -> Dict:
        c = self._get(cid)
        out = {
            "id": c.id,
            "state": c.state,
            "kind": c.kind,
            "spec": {**asdict(c.spec),
                     "objectives": list(c.spec.objectives)},
            "submitted_at": c.submitted_at,
            "started_at": c.started_at,
            "finished_at": c.finished_at,
            "error": c.error,
        }
        # live progress from the stepped campaign state machine (stage,
        # strategy, generation, labels requested) — not just queued/done
        if c.driver is not None and c.result is None:
            try:
                out["progress"] = c.driver.progress()
            except Exception:  # noqa: BLE001 - progress is best-effort
                pass
        sched = self.scheduler.campaign_stats(c.id)
        if sched:
            out["labeling"] = sched
        if c.result is not None:
            # _run sets c.result before the finally that stamps
            # finished_at, so a concurrent poll can land between the two
            fin = c.finished_at
            out["wall_s"] = (fin if fin is not None
                             else time.time()) - c.started_at
            out["val_pcc"] = c.result.val_pcc
            out["timings"] = c.result.timings
            out["front_size"] = int(c.result.front_mask.sum())
            if c.kind == "hierarchical":
                out["stage_campaigns"] = list(c.result.stage_campaign_ids)
                out["ground_truth_calls"] = dict(c.result.ground_truth_calls)
                out["flat_space_size"] = float(c.result.flat_space_size)
                out["max_concurrent_stages"] = int(
                    c.result.max_concurrent_stages)
        return out

    def campaign_timeline(self, cid: str) -> Dict:
        """Per-tick search telemetry series for one campaign (backs
        ``GET /campaigns/<id>/timeline``): hypervolume against the
        frozen per-campaign reference, front size, labels requested/
        served, store reuse rate, stage progress."""
        c = self._get(cid)
        out = {
            "id": cid,
            "state": c.state,
            "samples": self.timeline.series(cid),
        }
        ref = self.timeline.reference(cid)
        if ref is not None:
            out["hv_reference"] = ref
        return out

    def list_campaigns(self) -> List[Dict]:
        with self._lock:
            return [{"id": c.id, "state": c.state, "kind": c.kind,
                     "accel": c.spec.accel,
                     "strategy": getattr(c.spec, "strategy", None)}
                    for c in self._campaigns.values()]

    def result(self, cid: str) -> DSEResult:
        c = self._get(cid)
        if c.state == "failed":
            raise RuntimeError(f"campaign {cid} failed: {c.error}")
        if c.result is None:
            raise RuntimeError(f"campaign {cid} not finished (state={c.state})")
        return c.result

    def front(self, cid: str) -> Dict:
        """The campaign's true Pareto front as JSON-ready lists."""
        res = self.result(cid)
        return {
            "id": cid,
            "accel": res.accel_name,
            "objectives": list(res.config.objectives),
            "genomes": res.front_genomes.tolist(),
            "front": res.front_objectives.tolist(),
        }

    def global_front(self, accel: str,
                     objectives: Tuple[str, ...] = ("qor", "energy")) -> Dict:
        """Merged non-dominated front over every completed campaign for
        one accelerator (the service's cumulative Pareto knowledge)."""
        genomes: List[np.ndarray] = []
        objs: List[np.ndarray] = []
        sources: List[str] = []
        with self._lock:
            done = [c for c in self._campaigns.values()
                    if c.state == "done" and c.result is not None
                    and c.spec.accel == accel
                    and tuple(c.spec.objectives) == tuple(objectives)]
            # labels are only comparable within one evaluation context
            # (rank_genes changes genome width, n_qor_samples changes
            # qor values): merge the most recent campaign's context only
            if done:
                latest = max(done, key=lambda c: c.finished_at or 0.0)
                ctx = (latest.spec.rank_genes, latest.spec.n_qor_samples)
                done = [
                    c for c in done
                    if (c.spec.rank_genes, c.spec.n_qor_samples) == ctx
                ]
        for c in done:
            genomes.append(c.result.front_genomes)
            objs.append(c.result.front_objectives)
            sources += [c.id] * len(c.result.front_genomes)
        if not genomes:
            return {"accel": accel, "objectives": list(objectives),
                    "genomes": [], "front": [], "campaigns": []}
        G = np.concatenate(genomes)
        O = np.concatenate(objs)
        # dedupe identical genomes, then keep the non-dominated set
        _, uniq = np.unique(G, axis=0, return_index=True)
        G, O = G[uniq], O[uniq]
        src = [sources[i] for i in uniq]
        mask = non_dominated_mask(O)
        return {
            "accel": accel,
            "objectives": list(objectives),
            "genomes": G[mask].tolist(),
            "front": O[mask].tolist(),
            "campaigns": sorted({s for s, m in zip(src, mask) if m}),
        }

    # ------------------------------------------------------------------
    # serving tier
    # ------------------------------------------------------------------
    def subscribe_front(self, callback) -> None:
        """Register ``callback(accel_name)`` to fire after a campaign
        completes successfully — the serving tier's hot-swap signal."""
        with self._lock:
            self._front_listeners.append(callback)

    def _notify_front(self, accel: str) -> None:
        """Fire front listeners OUTSIDE the manager lock (a listener
        rebuilds a catalog via global_front, which takes it).  Listener
        failures never fail the campaign that triggered them."""
        with self._lock:
            listeners = list(self._front_listeners)
        for cb in listeners:
            try:
                cb(accel)
            except Exception:  # noqa: BLE001 - campaign isolation
                _log.exception("front listener failed for %s", accel)

    @property
    def serving(self):
        """The lazily-created ServingHub (one engine per accelerator)
        behind POST /serve.  Uses a dedicated lock: a serving request
        arriving while a campaign ticks must not contend on _lock."""
        with self._serving_lock:
            if self._serving is None:
                from ..serving import ServingHub

                self._serving = ServingHub(self, **self._serving_kw)
            return self._serving

    def serving_stats(self) -> Dict:
        """GET /serving/stats without forcing the hub into existence."""
        with self._serving_lock:
            hub = self._serving
        return hub.stats() if hub is not None else {"engines": {}}

    def stats(self) -> Dict:
        """The service's whole labeling economy in one JSON blob: label-
        store hits, in-flight dedup hits, coalesced batches (scheduler);
        per-backend labeler counters incl. the process pool's aggregated
        worker synthesis counters (scheduler.labeler); synth-cache hit
        rate and verification state (synth); fused behavioral-sim engine
        counters for THIS process (sim.fused — worker-process counters
        ride the labeler stats)."""
        from ..accel import fused
        from ..core.features import synth as synth_mod

        with self._lock:
            by_state: Dict[str, int] = {}
            for c in self._campaigns.values():
                by_state[c.state] = by_state.get(c.state, 0) + 1
        cache = (self.synth_cache if self.synth_cache is not None
                 else synth_mod.shared_synth_cache())
        out = {
            "campaigns": by_state,
            "scheduler": self.scheduler.stats(),
            "surrogates": self.registry.stats(),
            "synth": {
                "structural_keys": synth_mod.STRUCTURAL_KEYS,
                "fast_codegen": synth_mod.FAST_CODEGEN,
                "persistent": hasattr(cache, "path"),
                "cache": cache.stats(),
            },
            "sim": {
                "fused_enabled": fused.enabled(),
                "fused": fused.stats(),
            },
            "obs": {
                "tracing": obs.enabled(),
                "recorder": obs.recorder().stats(),
                "timeline_campaigns": len(self.timeline.campaigns()),
            },
        }
        with self._serving_lock:
            hub = self._serving
        if hub is not None:
            out["serving"] = hub.stats()
        return out

    def health(self) -> Dict:
        """Readiness/liveness in one JSON blob (``GET /health``): is
        the label store writable, is the scheduler's batcher thread
        alive, how many fleet workers are live (fleet backend only),
        which serving engines are up, and whether a fault plan is
        armed.  ``ok`` is the AND of the store and scheduler checks —
        an empty fleet or an idle serving hub is degraded, not dead."""
        from .. import faults

        store_h = self.store.health()
        sched_alive = self.scheduler._batcher.is_alive()
        out = {
            "store": store_h,
            "scheduler": {
                "alive": sched_alive,
                "backend": self.scheduler.backend,
            },
            "faults": faults.stats(),
        }
        fleet = getattr(self.scheduler, "fleet", None)
        if fleet is not None:
            fs = fleet.stats()
            out["fleet"] = {
                "registered": fs["registered"],
                "live": fs["live"],
                "leases_in_flight": fs["leases_in_flight"],
                "pending_chunks": fs["pending_chunks"],
            }
        with self._serving_lock:
            hub = self._serving
        if hub is not None:
            engines = {}
            with hub._lock:
                for name, eng in hub._engines.items():
                    engines[name] = {
                        "alive": eng._thread.is_alive(),
                        "queue_depth": len(eng._queue),
                    }
            out["serving"] = {"engines": engines}
        out["ok"] = bool(store_h.get("writable")) and sched_alive
        return out

    def shutdown(self, *, wait: bool = True) -> None:
        with self._serving_lock:
            hub, self._serving = self._serving, None
        if hub is not None:
            hub.close()
        self._hier_pool.shutdown(wait=wait)
        self._pool.shutdown(wait=wait)
        self.scheduler.shutdown(wait=wait)
        if self._owns_synth_cache and self.synth_cache is not None:
            self.synth_cache.close()
        with self._snap_lock:
            if self._snap_fh is not None:
                self._snap_fh.close()
                self._snap_fh = None
