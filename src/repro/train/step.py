"""Training step: CE loss (+ MoE aux), microbatched gradient accumulation
via lax.scan (shard-preserving microbatch split), AdamW update, optional
int8 error-feedback gradient compression.

The microbatch reshape keeps every device's rows local: (B, S) ->
(B/n_micro, n_micro, S) -> transpose -> scan over the micro axis; the
batch-sharded dim stays intact, so no cross-device data motion is
introduced by accumulation (DESIGN.md §5).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from ..models import ApproxPolicy, forward
from ..models.config import ModelConfig
from ..optim.adamw import AdamW
from ..optim.compress import ef_quantize

__all__ = ["cross_entropy", "make_loss_fn", "make_train_step", "init_state"]

AUX_COEF = 0.01


def cross_entropy(
    logits: jnp.ndarray,      # (b, s, padded_vocab)
    labels: jnp.ndarray,      # (b, s)
    vocab_size: int,
) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab_size:
        pad = jnp.arange(logits.shape[-1]) >= vocab_size
        logits = jnp.where(pad[None, None], -1e30, logits)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_loss_fn(cfg: ModelConfig, policy: Optional[ApproxPolicy] = None,
                 *, attn_chunk: int = 1024, scan_chunk: int = 128):
    def loss_fn(params, batch: Dict[str, jnp.ndarray]):
        logits, _, aux = forward(
            params, cfg,
            batch.get("tokens"),
            embeds=batch.get("embeds"),
            enc_embeds=batch.get("enc_embeds"),
            policy=policy, remat=True,
            attn_chunk=attn_chunk, scan_chunk=scan_chunk,
        )
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:
            # frontend prefix (vlm): loss only over the text positions
            logits = logits[:, -labels.shape[1]:]
        ce = cross_entropy(logits, labels, cfg.vocab_size)
        return ce + AUX_COEF * aux, {"ce": ce, "aux": aux}

    return loss_fn


def _split_micro(x: jnp.ndarray, n_micro: int) -> jnp.ndarray:
    """(B, ...) -> (n_micro, B/n_micro, ...), keeping the batch shards
    intact (see module docstring)."""
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    x = x.reshape(b // n_micro, n_micro, *x.shape[1:])
    x = jnp.moveaxis(x, 1, 0)
    return constrain(x, (None, "batch") + (None,) * (x.ndim - 2))


def init_state(params, opt: AdamW, *, compress: bool = False) -> Dict[str, Any]:
    state = {"params": params, "opt": opt.init(params)}
    if compress:
        state["ef_err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return state


def make_train_step(
    cfg: ModelConfig,
    opt: AdamW,
    *,
    n_micro: int = 1,
    policy: Optional[ApproxPolicy] = None,
    compress: bool = False,
    attn_chunk: int = 1024,
    scan_chunk: int = 128,
    acc_dtype: Optional[str] = None,   # gradient-accumulator dtype override
):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(cfg, policy, attn_chunk=attn_chunk,
                           scan_chunk=scan_chunk)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    # logical-axis shardings of every parameter (same declaration the
    # params were built from): gradients and their accumulators are
    # constrained to these — otherwise XLA keeps FSDP gradients
    # REPLICATED through the accumulation scan (tens of GB at 398B scale)
    from ..models.transformer import param_specs as _pspecs
    specs = _pspecs(cfg)

    def _constrain_like(tree):
        return jax.tree.map(
            lambda t, s: constrain(t, s.logical), tree, specs
        )

    def train_step(state: Dict[str, Any], batch: Dict[str, jnp.ndarray]):
        params = state["params"]
        if n_micro == 1:
            (loss, parts), grads = grad_fn(params, batch)
            grads = _constrain_like(grads)
        else:
            micro = {k: _split_micro(v, n_micro) for k, v in batch.items()}
            # accumulate in the master-weight dtype: f32 normally, bf16
            # for the bf16-master configs (jamba) where an f32 shadow
            # tree would blow the per-chip HBM budget
            if acc_dtype is not None:
                acc_dt = jnp.dtype(acc_dtype)
            else:
                acc_dt = (jnp.float32 if cfg.param_dtype == "float32"
                          else jnp.bfloat16)
            zeros = jax.tree.map(
                lambda s: constrain(jnp.zeros(s.shape, acc_dt), s.logical),
                specs,
            )

            def body(acc, mb):
                g_acc, loss_acc, ce_acc = acc
                (loss, parts), g = grad_fn(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     g_acc, _constrain_like(g))
                g_acc = _constrain_like(g_acc)
                return (g_acc, loss_acc + loss, ce_acc + parts["ce"]), None

            (grads, loss, ce), _ = jax.lax.scan(
                body, (zeros, jnp.zeros(()), jnp.zeros(())), micro
            )
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss, parts = loss / n_micro, {"ce": ce / n_micro, "aux": 0.0}

        if compress:
            pairs = jax.tree.map(ef_quantize, grads, state["ef_err"])
            grads = jax.tree.map(lambda t: t[0], pairs,
                                 is_leaf=lambda t: isinstance(t, tuple))
            new_err = jax.tree.map(lambda t: t[1], pairs,
                                   is_leaf=lambda t: isinstance(t, tuple))

        new_params, new_opt, opt_metrics = opt.update(grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt}
        if compress:
            new_state["ef_err"] = new_err
        metrics = {"loss": loss, **{k: v for k, v in parts.items()}, **opt_metrics}
        return new_state, metrics

    return train_step
