"""Behavioral models of W-bit approximate adders (default W=16).

Vectorized numpy models ``f(a, b) -> s`` over unsigned W-bit operands.
Families: lower-OR (LOA), truncated, carry-cut segmented (ETA-II-like),
and speculative carry (almost-correct adder).  These span the error-vs-cost
spectrum of the FPGA approximate-adder literature referenced by the paper
([13], [16]).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "add_exact",
    "add_loa",
    "add_trunc",
    "add_segmented",
    "add_eta1",
    "add_speculative",
]


def _uw(x, w: int) -> np.ndarray:
    return np.asarray(x, dtype=np.int64) & ((1 << w) - 1)


def add_exact(a, b, *, w: int = 16) -> np.ndarray:
    """Exact W-bit adder (full (W+1)-bit sum, no wraparound)."""
    return _uw(a, w) + _uw(b, w)


def add_loa(a, b, *, k: int, w: int = 16) -> np.ndarray:
    """Lower-OR adder: low k bits are a|b (no carry generated into the
    accurate upper (W-k)-bit adder)."""
    a, b = _uw(a, w), _uw(b, w)
    mask = (1 << k) - 1
    low = (a | b) & mask
    high = ((a >> k) + (b >> k)) << k
    return high + low


def add_trunc(a, b, *, k: int, w: int = 16) -> np.ndarray:
    """Truncated adder: low k bits of both operands are zeroed."""
    a, b = _uw(a, w), _uw(b, w)
    mask = ~np.int64((1 << k) - 1)
    return (a & mask) + (b & mask)


def add_segmented(a, b, *, seg: int, w: int = 16) -> np.ndarray:
    """Carry-cut segmented adder (ETA-II style): the adder is split into
    ceil(W/seg) independent segments; carries do not propagate across
    segment boundaries (each segment's carry-out is dropped, except the
    top segment which keeps its carry to preserve the (W+1)-bit range)."""
    a, b = _uw(a, w), _uw(b, w)
    out = np.zeros_like(a)
    nseg = (w + seg - 1) // seg
    for i in range(nseg):
        lo = i * seg
        width = min(seg, w - lo)
        m = (1 << width) - 1
        s = ((a >> lo) & m) + ((b >> lo) & m)
        if i < nseg - 1:
            s = s & m  # drop the segment carry-out
        out = out + (s << lo)
    return out


def add_eta1(a, b, *, k: int, w: int = 16) -> np.ndarray:
    """Error-tolerant adder type I (Zhu et al.): exact upper part; the low
    k bits are produced MSB->LSB until the first position where both
    operand bits are 1, after which every lower output bit is forced to 1.
    """
    a, b = _uw(a, w), _uw(b, w)
    low = np.zeros_like(a)
    flood = np.zeros_like(a, dtype=bool)
    for i in range(k - 1, -1, -1):
        ai = (a >> i) & 1
        bi = (b >> i) & 1
        both = (ai & bi).astype(bool)
        bit = np.where(flood, 1, ai | bi)
        low = low | (bit << i)
        flood = flood | both
    high = ((a >> k) + (b >> k)) << k
    return high + low


def add_speculative(a, b, *, la: int, w: int = 16) -> np.ndarray:
    """Almost-correct adder: each sum bit i uses a carry speculated from
    only the previous `la` bit positions (carry lookahead window).  Exact
    when the true carry chain is shorter than `la`."""
    a, b = _uw(a, w), _uw(b, w)
    out = np.zeros_like(a)
    for i in range(w + 1):
        lo = max(0, i - la)
        # carry into bit i computed from the window [lo, i)
        aw = (a >> lo) & ((1 << (i - lo)) - 1)
        bw = (b >> lo) & ((1 << (i - lo)) - 1)
        carry = ((aw + bw) >> (i - lo)) & 1 if i > lo else np.zeros_like(a)
        ai = (a >> i) & 1
        bi = (b >> i) & 1
        out = out | (((ai + bi + carry) & 1) << i)
    return out
