"""Kernel / instance-based / neural surrogates: RBF kernel ridge, epsilon-SVR
(the paper's third Fig. 6 contender), kNN, and a small MLP (cited by [15]
as inferior to statistical regression — included for the ablation)."""

from __future__ import annotations

import numpy as np

from .base import Model

__all__ = ["KernelRidgeRBF", "SVR", "KNN", "MLP"]


def _rbf(A: np.ndarray, B: np.ndarray, gamma: float) -> np.ndarray:
    d2 = (
        (A**2).sum(axis=1)[:, None]
        + (B**2).sum(axis=1)[None, :]
        - 2.0 * A @ B.T
    )
    return np.exp(-gamma * np.maximum(d2, 0.0))


class KernelRidgeRBF(Model):
    def __init__(self, alpha: float = 0.1, gamma: float = 0.5, seed: int = 0):
        super().__init__(seed)
        self.alpha, self.gamma = alpha, gamma

    def _fit(self, X, y):
        self.Xtr = X
        K = _rbf(X, X, self.gamma)
        self.dual = np.linalg.solve(K + self.alpha * np.eye(len(X)), y)

    def _predict(self, X):
        return _rbf(X, self.Xtr, self.gamma) @ self.dual


class SVR(Model):
    """Epsilon-insensitive support vector regression, solved in the primal
    by subgradient descent over random Fourier features (RBF kernel
    approximation).  From-scratch stand-in for sklearn's SVR."""

    def __init__(
        self,
        C: float = 10.0,
        epsilon: float = 0.05,
        gamma: float = 0.05,
        n_features: int = 512,
        epochs: int = 1000,
        lr: float = 0.05,
        seed: int = 0,
    ):
        super().__init__(seed)
        self.C, self.epsilon, self.gamma = C, epsilon, gamma
        self.n_features, self.epochs, self.lr = n_features, epochs, lr

    def _phi(self, X):
        z = X @ self.W + self.b0
        return np.sqrt(2.0 / self.n_features) * np.cos(z)

    def _fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        d = X.shape[1]
        self.W = rng.normal(0.0, np.sqrt(2 * self.gamma), size=(d, self.n_features))
        self.b0 = rng.uniform(0, 2 * np.pi, size=self.n_features)
        P = self._phi(X)
        n = len(y)
        w = np.zeros(self.n_features)
        b = 0.0
        for ep in range(self.epochs):
            lr = self.lr / (1.0 + 0.01 * ep)
            pred = P @ w + b
            r = pred - y
            g = np.where(r > self.epsilon, 1.0, np.where(r < -self.epsilon, -1.0, 0.0))
            grad_w = w / (self.C * n) + P.T @ g / n
            w -= lr * grad_w
            b -= lr * g.mean()
        self.w, self.b = w, b

    def _predict(self, X):
        return self._phi(X) @ self.w + self.b


class KNN(Model):
    standardize_y = False

    def __init__(self, k: int = 5, weighted: bool = True, seed: int = 0):
        super().__init__(seed)
        self.k, self.weighted = k, weighted

    def _fit(self, X, y):
        self.Xtr, self.ytr = X, y

    def _predict(self, X):
        d2 = (
            (X**2).sum(axis=1)[:, None]
            + (self.Xtr**2).sum(axis=1)[None, :]
            - 2.0 * X @ self.Xtr.T
        )
        k = min(self.k, len(self.ytr))
        idx = np.argpartition(d2, k - 1, axis=1)[:, :k]
        dd = np.take_along_axis(d2, idx, axis=1)
        yy = self.ytr[idx]
        if not self.weighted:
            return yy.mean(axis=1)
        w = 1.0 / (np.sqrt(np.maximum(dd, 0)) + 1e-9)
        return (yy * w).sum(axis=1) / w.sum(axis=1)


class MLP(Model):
    """Two-hidden-layer tanh MLP trained with Adam (full-batch)."""

    def __init__(self, hidden: int = 64, epochs: int = 500, lr: float = 1e-2, seed: int = 0):
        super().__init__(seed)
        self.hidden, self.epochs, self.lr = hidden, epochs, lr

    def _fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        d, h = X.shape[1], self.hidden
        p = {
            "W1": rng.normal(0, 1 / np.sqrt(d), (d, h)),
            "b1": np.zeros(h),
            "W2": rng.normal(0, 1 / np.sqrt(h), (h, h)),
            "b2": np.zeros(h),
            "W3": rng.normal(0, 1 / np.sqrt(h), (h, 1)),
            "b3": np.zeros(1),
        }
        m = {k: np.zeros_like(v) for k, v in p.items()}
        v = {k: np.zeros_like(val) for k, val in p.items()}
        b1, b2, eps = 0.9, 0.999, 1e-8
        y = y[:, None]
        for t in range(1, self.epochs + 1):
            h1 = np.tanh(X @ p["W1"] + p["b1"])
            h2 = np.tanh(h1 @ p["W2"] + p["b2"])
            out = h2 @ p["W3"] + p["b3"]
            dout = 2.0 * (out - y) / len(y)
            g = {}
            g["W3"] = h2.T @ dout
            g["b3"] = dout.sum(axis=0)
            dh2 = (dout @ p["W3"].T) * (1 - h2**2)
            g["W2"] = h1.T @ dh2
            g["b2"] = dh2.sum(axis=0)
            dh1 = (dh2 @ p["W2"].T) * (1 - h1**2)
            g["W1"] = X.T @ dh1
            g["b1"] = dh1.sum(axis=0)
            for k in p:
                m[k] = b1 * m[k] + (1 - b1) * g[k]
                v[k] = b2 * v[k] + (1 - b2) * g[k] ** 2
                mh = m[k] / (1 - b1**t)
                vh = v[k] / (1 - b2**t)
                p[k] -= self.lr * mh / (np.sqrt(vh) + eps)
        self.p = p

    def _predict(self, X):
        h1 = np.tanh(X @ self.p["W1"] + self.p["b1"])
        h2 = np.tanh(h1 @ self.p["W2"] + self.p["b2"])
        return (h2 @ self.p["W3"] + self.p["b3"]).ravel()
