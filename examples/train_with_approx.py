"""End-to-end training driver example: train a reduced granite-8b for a
few hundred steps, with and without the paper's approximate-projection
policy, with checkpointing + an injected failure + automatic restart.

    PYTHONPATH=src python examples/train_with_approx.py [--steps 200]
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_config
from repro.launch.train import train_loop
from repro.models import ApproxPolicy, reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced(get_config("granite-8b"))
    print(f"config: {cfg.name}  layers={cfg.n_layers} d={cfg.d_model}")

    print("\n--- exact baseline ---")
    _, base = train_loop(cfg, steps=args.steps, batch=args.batch,
                         seq=args.seq, lr=5e-3, n_micro=2, log_every=50)

    print("\n--- with approximate FFN projections (mul8s_trunc2, native "
          "int6-ish deployment) ---")
    pol = ApproxPolicy({"ffn_in": ("mul8s_trunc2", None),
                        "ffn_out": ("mul8s_trunc2", None)})
    _, approx = train_loop(cfg, steps=args.steps, batch=args.batch,
                           seq=args.seq, lr=5e-3, n_micro=2, policy=pol,
                           log_every=50)

    print("\n--- fault-tolerant run (checkpoint + resume) ---")
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ck_")
    half = args.steps // 2
    train_loop(cfg, steps=half, batch=args.batch, seq=args.seq, lr=5e-3,
               ckpt_dir=ckpt_dir, ckpt_every=25, log_every=50)
    print("(simulated preemption; restarting from latest checkpoint)")
    _, resumed = train_loop(cfg, steps=args.steps, batch=args.batch,
                            seq=args.seq, lr=5e-3, ckpt_dir=ckpt_dir,
                            ckpt_every=25, log_every=50)

    print(f"\nfinal losses: exact={np.mean(base[-5:]):.4f}  "
          f"approx={np.mean(approx[-5:]):.4f}  "
          f"resumed={np.mean(resumed[-5:]):.4f}")
    print("the approximate run tracks the exact run (trunc2 is a mild "
          "circuit); the resumed run continued from the checkpoint.")


if __name__ == "__main__":
    main()
