"""The approximate-circuit library (ACL) registry.

This is the JAX-side equivalent of the paper's EvoApprox8b library [22]:
a catalogue of 8-bit approximate multipliers and 16-bit approximate adders,
each carrying

  * a behavioral model (``fn``) — bit-exact vectorized numpy,
  * an exhaustive product table (multipliers) and error table,
  * error statistics (the QoR-surrogate features of the paper),
  * a low-rank SVD factorization of the error table (the TPU deployment
    path, DESIGN.md §2),
  * closed-form *structural* cost features (the ABC-analogue features) and
  * a reference hardware cost on the target TPU (roofline energy/latency
    contribution per MAC — the Vivado-analogue label is produced by
    ``core.features.synth``, not here).

Everything is cached on first access: the registry is cheap to import.
"""

from __future__ import annotations

import functools
import hashlib
import weakref
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from . import adders, multipliers, tables

__all__ = [
    "Circuit",
    "Library",
    "default_library",
    "library_fingerprint",
    "MUL8U",
    "MUL8S",
    "ADD16",
]


@dataclass(frozen=True)
class Circuit:
    """One approximate circuit: behavioral model + cached analyses."""

    name: str
    kind: str  # "mul8u" | "mul8s" | "add16"
    fn: Callable  # vectorized numpy behavioral model
    # Structural knobs (used by the cheap feature extractor):
    trunc_bits: int = 0       # LSBs removed from the datapath
    pp_rows: int = 8          # partial-product rows kept (multipliers)
    carry_window: int = 16    # longest exact carry chain (adders)
    is_exact: bool = False
    # Operand-truncation circuits deploy NATIVELY on the MXU as a
    # reduced-width integer matmul (no correction terms): the truncation
    # IS the quantization.  None for every other family.
    native_width: Optional[int] = None
    _cache: dict = field(default_factory=dict, compare=False, repr=False)

    @property
    def deploy_width(self) -> int:
        """Integer operand width of the MXU deployment (8 = int8 base)."""
        return self.native_width if self.native_width is not None else 8

    @property
    def deploy_rank(self) -> int:
        """Correction rank of the faithful deployment: 0 for exact and for
        natively-deployable truncations, eff_rank otherwise."""
        if self.kind == "add16" or self.is_exact or self.native_width is not None:
            return 0
        return self.eff_rank

    def deploy_cost_factor(self) -> float:
        """Relative MAC cost of this circuit's faithful MXU deployment vs
        ONE bf16 MAC: base matmul at deploy_width + deploy_rank bf16
        correction matmuls (DESIGN.md §2; the TPU-native Pareto driver —
        on the MXU, power-of-two truncations are the cheap family, exotic
        logic-level circuits cost MORE than exact)."""
        from .. import hw

        base = hw.V5E.dtype_cost_factor(self.deploy_width)
        if self.kind == "add16":
            return 0.0  # adders ride the MXU accumulators for free
        return base + float(self.deploy_rank)

    # ---- cached heavy analyses -------------------------------------------------
    def _get(self, key, builder):
        if key not in self._cache:
            self._cache[key] = builder()
        return self._cache[key]

    @property
    def signed(self) -> bool:
        return self.kind == "mul8s"

    @property
    def table(self) -> np.ndarray:
        """(256,256) exhaustive product table (multipliers only)."""
        if self.kind == "add16":
            raise ValueError("adders are not exhaustively tabulated")
        builder = (
            (lambda: tables.product_table_s8(self.fn))
            if self.signed
            else (lambda: tables.product_table_u8(self.fn))
        )
        return self._get("table", builder)

    @property
    def etab(self) -> np.ndarray:
        """(256,256) error table E = approx - exact (multipliers only)."""
        return self._get(
            "etab", lambda: tables.error_table(self.table, signed=self.signed)
        )

    @property
    def stats(self) -> tables.ErrorStats:
        if self.kind == "add16":
            return self._get("stats", lambda: tables.adder_error_stats(self.fn))
        return self._get(
            "stats", lambda: tables.error_stats(self.table, signed=self.signed)
        )

    @property
    def eff_rank(self) -> int:
        """Effective rank of the error table at 99% energy (TPU deployment
        cost driver: rank-k correction = k extra MXU matmuls)."""
        if self.kind == "add16":
            return 0  # adders deploy as elementwise maps, no matmul correction
        if self.is_exact:
            return 0
        return self._get("eff_rank", lambda: tables.effective_rank(self.etab))

    def factors(self, rank: int) -> tables.RankFactors:
        key = ("factors", rank)
        return self._get(key, lambda: tables.svd_factors(self.etab, rank))

    # ---- cheap structural cost features (ABC analogue, per-MAC) ----------------
    @property
    def structural_features(self) -> np.ndarray:
        """Closed-form per-circuit cost proxies.  Mirrors the role of ABC's
        AIG statistics in the paper: fast, synthesis-free, correlated with
        the true hardware cost.  Order: [pp_rows, 8-trunc_bits,
        carry_window, eff_rank, log10(1+mse), mae, ep]."""

        def build():
            s = self.stats
            return np.array(
                [
                    float(self.pp_rows),
                    float(8 - self.trunc_bits),
                    float(self.carry_window),
                    float(self.eff_rank),
                    np.log10(1.0 + s.mse),
                    s.mae,
                    s.ep,
                ]
            )

        return self._get("sfeat", build)

    @property
    def error_features(self) -> np.ndarray:
        """The QoR-surrogate inputs: 'mean and average error' (paper §III)
        plus the extended AC benchmarking metrics."""
        return self.stats.as_array()


def _mk_mul(name, fn, **kw) -> Circuit:
    return Circuit(name=name, kind="mul8u", fn=fn, **kw)


def _mk_muls(name, fn, **kw) -> Circuit:
    return Circuit(name=name, kind="mul8s", fn=multipliers.signed_wrap(fn), **kw)


def _mk_add(name, fn, **kw) -> Circuit:
    return Circuit(name=name, kind="add16", fn=fn, **kw)


def _build_mul8u() -> List[Circuit]:
    out = [_mk_mul("mul8u_exact", multipliers.mul8_exact, is_exact=True)]
    for k in range(1, 7):
        out.append(
            _mk_mul(
                f"mul8u_trunc{k}",
                functools.partial(multipliers.mul8_trunc, k=k),
                trunc_bits=k,
                pp_rows=8 - k,
                native_width=8 - k,
            )
        )
    for k in range(1, 7):
        out.append(
            _mk_mul(
                f"mul8u_perf{k}",
                functools.partial(multipliers.mul8_perforated, k=k),
                pp_rows=8 - k,
            )
        )
    for k in range(2, 9, 2):
        out.append(
            _mk_mul(
                f"mul8u_bam{k}",
                functools.partial(multipliers.mul8_broken_array, k=k),
                trunc_bits=k // 2,
            )
        )
    out.append(_mk_mul("mul8u_mitchell", multipliers.mul8_mitchell, pp_rows=2))
    for k in range(3, 7):
        out.append(
            _mk_mul(
                f"mul8u_drum{k}",
                functools.partial(multipliers.mul8_drum, k=k),
                pp_rows=k,
            )
        )
    out.append(_mk_mul("mul8u_kulkarni", multipliers.mul8_kulkarni, pp_rows=7))
    return out


def _build_mul8s() -> List[Circuit]:
    out = [
        Circuit(
            name="mul8s_exact",
            kind="mul8s",
            fn=multipliers.signed_wrap(multipliers.mul8_exact),
            is_exact=True,
        )
    ]
    for k in range(1, 7):
        out.append(
            _mk_muls(
                f"mul8s_trunc{k}",
                functools.partial(multipliers.mul8_trunc, k=k),
                trunc_bits=k,
                pp_rows=8 - k,
                native_width=8 - k,
            )
        )
    for k in range(1, 7):
        out.append(
            _mk_muls(
                f"mul8s_perf{k}",
                functools.partial(multipliers.mul8_perforated, k=k),
                pp_rows=8 - k,
            )
        )
    out.append(_mk_muls("mul8s_mitchell", multipliers.mul8_mitchell, pp_rows=2))
    for k in range(3, 7):
        out.append(
            _mk_muls(
                f"mul8s_drum{k}",
                functools.partial(multipliers.mul8_drum, k=k),
                pp_rows=k,
            )
        )
    out.append(_mk_muls("mul8s_kulkarni", multipliers.mul8_kulkarni, pp_rows=7))
    return out


def _build_add16() -> List[Circuit]:
    out = [_mk_add("add16_exact", adders.add_exact, is_exact=True)]
    for k in range(2, 9, 2):
        out.append(
            _mk_add(
                f"add16_loa{k}",
                functools.partial(adders.add_loa, k=k),
                trunc_bits=k,
                carry_window=16 - k,
            )
        )
    for k in range(2, 9, 2):
        out.append(
            _mk_add(
                f"add16_trunc{k}",
                functools.partial(adders.add_trunc, k=k),
                trunc_bits=k,
                carry_window=16 - k,
            )
        )
    for seg in (4, 8):
        out.append(
            _mk_add(
                f"add16_seg{seg}",
                functools.partial(adders.add_segmented, seg=seg),
                carry_window=seg,
            )
        )
    for k in (4, 8):
        out.append(
            _mk_add(
                f"add16_eta1_{k}",
                functools.partial(adders.add_eta1, k=k),
                carry_window=16 - k,
            )
        )
    for la in (4, 8):
        out.append(
            _mk_add(
                f"add16_aca{la}",
                functools.partial(adders.add_speculative, la=la),
                carry_window=la,
            )
        )
    return out


class Library:
    """A named collection of circuits, indexable by kind and by name.

    The DSE genome stores *indices into a kind's circuit list*, so the
    library object is the single source of truth for genome decoding.
    """

    def __init__(self, circuits: List[Circuit]):
        self.circuits = list(circuits)
        self.by_name: Dict[str, Circuit] = {c.name: c for c in self.circuits}
        self.by_kind: Dict[str, List[Circuit]] = {}
        for c in self.circuits:
            self.by_kind.setdefault(c.kind, []).append(c)

    def __len__(self) -> int:
        return len(self.circuits)

    def __getitem__(self, name: str) -> Circuit:
        return self.by_name[name]

    def kind(self, kind: str) -> List[Circuit]:
        return self.by_kind[kind]

    def index(self, kind: str, name: str) -> int:
        return [c.name for c in self.by_kind[kind]].index(name)

    def exact_index(self, kind: str) -> int:
        for i, c in enumerate(self.by_kind[kind]):
            if c.is_exact:
                return i
        raise ValueError(f"no exact circuit of kind {kind}")

    def subset(self, names) -> "Library":
        return Library([self.by_name[n] for n in names])


@functools.lru_cache(maxsize=1)
def default_library() -> Library:
    return Library(_build_mul8u() + _build_mul8s() + _build_add16())


# fixed probe operands per circuit kind for behavioral fingerprinting
_PROBE_OPS = {
    "mul8u": (np.arange(0, 256, 15, dtype=np.int64),
              np.arange(255, -1, -15, dtype=np.int64)),
    "mul8s": (np.arange(-128, 128, 15, dtype=np.int64),
              np.arange(127, -129, -15, dtype=np.int64)),
    "add16": (np.arange(-32768, 32768, 3855, dtype=np.int64),
              np.arange(32767, -32769, -3855, dtype=np.int64)),
}

# Memoized per live Library OBJECT: weak keys cannot alias two libraries
# the way ``id(library)`` can after the first is collected and the id is
# reused, and content-equal libraries hash to the same digest anyway.
_FP_MEMO: "weakref.WeakKeyDictionary[Library, str]" = weakref.WeakKeyDictionary()


def library_fingerprint(library: Library) -> str:
    """Content digest of the genome decoding map AND circuit behavior.

    Genomes store indices into the per-kind lists, so order and names
    matter — but so does each circuit's behavior: structural knobs plus
    a fixed behavioral probe of ``fn`` are hashed so that editing a
    circuit without renaming it re-keys every content-addressed consumer
    (label store, LUT caches, fused-sim jit cache) instead of serving
    stale state."""
    fp = _FP_MEMO.get(library)
    if fp is not None:
        return fp
    h = hashlib.sha256()
    for kind, circuits in sorted(library.by_kind.items()):
        for c in circuits:
            h.update(repr((kind, c.name, c.trunc_bits, c.pp_rows,
                           c.carry_window, bool(c.is_exact),
                           c.native_width)).encode())
            probe = _PROBE_OPS.get(kind)
            if probe is not None:
                out = np.asarray(c.fn(*probe)).astype(np.int64)
                h.update(out.tobytes())
    fp = h.hexdigest()[:16]
    _FP_MEMO[library] = fp
    return fp


# Convenience kind constants
MUL8U = "mul8u"
MUL8S = "mul8s"
ADD16 = "add16"
