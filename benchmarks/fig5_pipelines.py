"""Fig. 5 — pipelines (A)-(F): Pearson correlation vs exploration time.

Reproduces the paper's qualitative result: the synthesis-feature
pipelines (B/E) are accurate but slow to set up; the cheap-feature
pipelines (C/D/F) explore a million variants in minutes; (D) keeps
PCC ~ (B/E) at ~cheap cost -> the framework's default.
"""

from __future__ import annotations

import numpy as np

from repro.accel import MCMAccelerator
from repro.core.acl.library import default_library
from repro.core.features import synth
from repro.core.features.pipelines import PIPELINES, evaluate_pipeline

from .common import emit, time_fn


def run(n_train: int = 80, n_test: int = 40, seed: int = 0):
    lib = default_library()
    accel = MCMAccelerator(0)
    rng = np.random.default_rng(seed)
    sizes = accel.gene_sizes(lib)
    genomes = rng.integers(0, sizes[None, :],
                           size=(n_train + n_test, len(sizes)))
    labels = synth.label_variants(accel, genomes, lib, cache={})
    tr = {k: v[:n_train] for k, v in labels.items()}
    te = {k: v[n_train:] for k, v in labels.items()}

    reports = {}
    for p in PIPELINES:
        rep = evaluate_pipeline(
            p, accel, lib, genomes[:n_train], tr, genomes[n_train:], te,
        )
        reports[p] = rep
        emit(f"fig5.{p}.pcc_hw", rep.per_variant_time * 1e6,
             round(rep.pcc_hw, 3))
        emit(f"fig5.{p}.pcc_qor", rep.per_variant_time * 1e6,
             round(rep.pcc_qor, 3))
        emit(f"fig5.{p}.explore_1M_hours", 0.0,
             round(rep.explore_time_1m / 3600, 3))

    # the paper's ordering claims, as derived booleans
    ok_speed = (reports["D"].explore_time_1m < reports["A"].explore_time_1m / 20
                and reports["D"].per_variant_time
                < reports["A"].per_variant_time / 10)
    ok_pcc = reports["D"].pcc_hw > 0.85 * max(
        reports["B"].pcc_hw, reports["F"].pcc_hw
    )
    emit("fig5.claim_D_fast", 0.0, int(ok_speed))
    emit("fig5.claim_D_accurate", 0.0, int(ok_pcc))
    return reports
