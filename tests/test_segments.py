"""Segmented persistence tier: CRC framing, fixed-size sealing, O(1)
warm start, quarantine-and-continue, torn-tail repair, retention,
orphan adoption, and the legacy single-file migration path."""

import json
import os

import pytest

from repro import faults
from repro.faults import FaultPlan
from repro.segments import SegmentedLog, frame_record, parse_line
from repro.service.store import (
    JsonlLabelStore,
    SegmentedLabelStore,
    open_label_store,
)

LABELS = None  # filled lazily from LABEL_KEYS


def _rec(i):
    global LABELS
    if LABELS is None:
        from repro.service.store import LABEL_KEYS
        LABELS = list(LABEL_KEYS)
    return {k: float(i * 10 + j) for j, k in enumerate(LABELS)}


def _fill(store, n, start=0):
    store.put_many((f"k{start + i:05d}", _rec(start + i))
                   for i in range(n))


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def test_frame_parse_roundtrip():
    line = frame_record({"a": 1, "b": [2, 3]})
    assert line.endswith("\n")
    assert parse_line(line[:-1]) == {"a": 1, "b": [2, 3]}


def test_parse_rejects_damage():
    good = frame_record({"x": 1})[:-1]
    assert parse_line(good[:-2]) is None                 # torn
    assert parse_line("zz" + good[2:]) is None           # bad crc hex
    flipped = good[:12] + ("0" if good[12] != "0" else "1") + good[13:]
    assert parse_line(flipped) is None                   # bit flip
    assert parse_line(good + good) is None               # merged lines
    assert parse_line("short") is None


# ---------------------------------------------------------------------------
# segmented label store: seal, warm start, lazy load
# ---------------------------------------------------------------------------

def test_fixed_size_seals_and_roundtrip(tmp_path):
    root = str(tmp_path / "labels.segd")
    s = SegmentedLabelStore(root, segment_records=5)
    _fill(s, 12)
    st = s.stats()
    assert st["segments"] == 2 and st["active_records"] == 2
    assert s.get("k00000") == _rec(0)
    assert s.get("k00011") == _rec(11)
    assert len(s) == 12
    s.close()
    names = sorted(os.listdir(root))
    assert "seg-000001.jsonl" in names and "seg-000001.idx" in names


def test_warm_start_is_lazy(tmp_path):
    root = str(tmp_path / "labels.segd")
    s = SegmentedLabelStore(root, segment_records=4)
    _fill(s, 17)
    s.close()

    s2 = SegmentedLabelStore(root, segment_records=4)
    # the whole index is visible WITHOUT parsing one sealed body
    assert len(s2) == 17
    assert s2.segments_loaded == 0
    # reading a sealed key loads exactly that segment
    assert s2.get("k00000") == _rec(0)
    assert s2.segments_loaded == 1
    # tail records were never sealed: no load needed
    assert s2.get("k00016") == _rec(16)
    assert s2.segments_loaded == 1
    s2.close()


def test_corrupt_segment_quarantined_and_store_continues(tmp_path):
    root = str(tmp_path / "labels.segd")
    s = SegmentedLabelStore(root, segment_records=4)
    _fill(s, 12)
    s.close()

    # flip bytes in the middle of a sealed segment
    victim = os.path.join(root, "seg-000002.jsonl")
    with open(victim, "r+") as f:
        f.seek(20)
        f.write("XXXX")

    s2 = SegmentedLabelStore(root, segment_records=4)
    assert len(s2) == 12              # sidecar index: damage unseen yet
    # touching a key in the damaged segment quarantines it; its keys
    # become clean misses while everything else keeps answering
    assert s2.get("k00004") is None
    st = s2.stats()
    assert st["quarantined_segments"] == 1
    assert st["quarantined"] >= 1
    assert os.path.exists(
        os.path.join(root, "quarantine", "seg-000002.jsonl"))
    assert s2.get("k00000") == _rec(0)       # other segments fine
    assert s2.get("k00008") == _rec(8)
    # the miss can be relabeled and the store moves on
    s2.put("k00004", _rec(4))
    assert s2.get("k00004") == _rec(4)
    s2.close()


def test_torn_tail_repaired_not_merged(tmp_path):
    root = str(tmp_path / "labels.segd")
    s = SegmentedLabelStore(root, segment_records=100)
    _fill(s, 3)
    # a foreign writer dies mid-append: partial record, no newline
    with open(os.path.join(root, "active.jsonl"), "a") as f:
        f.write(frame_record({"k": "kdead", "l": _rec(99)})[:30])
    # our next append must quarantine the fragment ALONE — not merge
    # it with (and destroy) the first fresh record
    _fill(s, 2, start=3)
    st = s.stats()
    assert st["repaired_tails"] == 1 and st["quarantined"] == 1
    s.close()

    s2 = SegmentedLabelStore(root)
    assert len(s2) == 5
    for i in range(5):
        assert s2.get(f"k{i:05d}") == _rec(i)
    assert s2.get("kdead") is None
    s2.close()


def test_injected_torn_write_never_loses_labels(tmp_path):
    root = str(tmp_path / "labels.segd")
    faults.install(FaultPlan(seed=2).add(
        "store.append", "torn_write", times=3, fraction=0.4))
    s = SegmentedLabelStore(root, segment_records=6)
    for i in range(5):                       # 5 appends, 3 injections
        _fill(s, 4, start=4 * i)
    faults.uninstall()
    assert s.stats()["repaired_tails"] >= 2  # first append has no tail
    s.close()

    s2 = SegmentedLabelStore(root)
    for i in range(20):
        assert s2.get(f"k{i:05d}") == _rec(i), f"label {i} lost"
    s2.close()


def test_orphan_segment_adopted_on_open(tmp_path):
    """A sealer killed between rename and manifest write leaves an
    orphan seg file; the next open adopts it, records intact."""
    root = str(tmp_path / "labels.segd")
    s = SegmentedLabelStore(root, segment_records=4)
    _fill(s, 6)       # one sealed segment + 2-record tail
    s.close()

    # simulate the crash window: a sealed file the manifest never saw
    orphan = os.path.join(root, "seg-000009.jsonl")
    with open(orphan, "w") as f:
        f.write(frame_record({"k": "korphan", "l": _rec(42),
                              "t": 0.0}))
    s2 = SegmentedLabelStore(root, segment_records=4)
    assert s2.get("korphan") == _rec(42)
    m = s2._seglog.manifest()
    assert any(e["name"] == "seg-000009.jsonl" for e in m["sealed"])
    s2.close()


def test_retention_evicts_oldest_segments(tmp_path):
    root = str(tmp_path / "labels.segd")
    s = SegmentedLabelStore(root, segment_records=3,
                            retention_segments=2)
    _fill(s, 12)      # 4 seals -> oldest 2 evicted
    assert s.stats()["segments"] == 2
    assert s.get("k00000") is None        # evicted -> clean miss
    assert s.get("k00011") == _rec(11)    # recent survives
    s.close()


def test_multiwriter_instances_share_one_root(tmp_path):
    root = str(tmp_path / "labels.segd")
    a = SegmentedLabelStore(root, segment_records=4)
    b = SegmentedLabelStore(root, segment_records=4)
    _fill(a, 6)
    _fill(b, 6, start=6)
    a.refresh()
    b.refresh()
    assert len(a) == 12 and len(b) == 12
    assert a.get("k00009") == _rec(9)
    assert b.get("k00002") == _rec(2)
    a.close()
    b.close()


def test_store_lock_latency_fault_applies(tmp_path):
    import time as _time

    faults.install(FaultPlan().add("store.lock", "latency",
                                   delay_s=0.05, times=1))
    t0 = _time.perf_counter()
    s = SegmentedLabelStore(str(tmp_path / "l.segd"))
    assert _time.perf_counter() - t0 >= 0.04
    s.close()


# ---------------------------------------------------------------------------
# legacy single-file stores: counted quarantine + migration
# ---------------------------------------------------------------------------

def test_jsonl_store_counts_torn_tail_and_malformed(tmp_path):
    path = str(tmp_path / "labels.jsonl")
    s = JsonlLabelStore(path)
    _fill(s, 2)
    s.close()
    with open(path, "a") as f:
        f.write('{"broken json\n')           # malformed complete line
        f.write('{"k": "kdead", "l": {')     # torn tail, no newline

    s2 = JsonlLabelStore(path)
    assert s2.quarantined == 1               # the malformed line
    _fill(s2, 1, start=2)                    # append repairs the tail
    assert s2.quarantined == 2
    assert s2.stats()["quarantined"] == 2
    s2.close()

    s3 = JsonlLabelStore(path)               # replay sees clean lines
    assert len(s3) == 3
    for i in range(3):
        assert s3.get(f"k{i:05d}") == _rec(i)
    s3.close()


def test_migration_check(tmp_path):
    """The examples-smoke migration node: a legacy .jsonl opens as a
    segmented store with every record answering warm, the old file is
    kept as evidence, and replicas resolve the migrated root."""
    path = str(tmp_path / "labels.jsonl")
    legacy = JsonlLabelStore(path)
    _fill(legacy, 8)
    legacy.close()

    # replicas never migrate: same path -> still the legacy store
    r = open_label_store(path)
    assert isinstance(r, JsonlLabelStore)
    r.close()

    s = open_label_store(path, migrate=True)
    assert isinstance(s, SegmentedLabelStore)
    assert len(s) == 8
    for i in range(8):
        assert s.get(f"k{i:05d}") == _rec(i)
    assert not os.path.exists(path)
    assert os.path.exists(path + ".migrated")
    s.close()

    # post-migration, a replica handed the ORIGINAL path resolves the
    # segmented root (the parent renamed the file away)
    r2 = open_label_store(path)
    assert isinstance(r2, SegmentedLabelStore)
    assert len(r2) == 8
    r2.close()


def test_open_label_store_plain_root(tmp_path):
    s = open_label_store(str(tmp_path / "labels"))
    assert isinstance(s, SegmentedLabelStore)
    _fill(s, 2)
    s.close()


# ---------------------------------------------------------------------------
# segmented synth cache
# ---------------------------------------------------------------------------

def test_segmented_synth_cache_roundtrip(tmp_path):
    from repro.core.features.synth import (
        JsonlSynthCache,
        SegmentedSynthCache,
        open_synth_cache,
    )

    root = str(tmp_path / "synth.segd")
    c = SegmentedSynthCache(root, segment_records=3)
    for i in range(7):
        c.store({"k": f"id{i}", "flops": float(i),
                 "hbm_bytes": float(i * 2)})
    c.verdict_pass("famA")                      # countdown ticks down
    c.verdict_pin("famB")                       # proven divergent
    passed = c.verdict("famA")
    assert c.stats()["segments"] >= 2
    c.close()

    c2 = SegmentedSynthCache(root, segment_records=3)
    assert c2.get_identity("id3")["flops"] == 3.0
    assert c2.verdict("famA") == passed          # progress persisted
    assert c2.verdict("famB") is False           # pin persisted
    c2.close()

    # legacy migration
    jpath = str(tmp_path / "legacy.jsonl")
    j = JsonlSynthCache(jpath)
    j.store({"k": "idX", "flops": 1.0, "hbm_bytes": 2.0})
    j.close()
    m = open_synth_cache(jpath, migrate=True)
    assert isinstance(m, SegmentedSynthCache)
    assert m.get_identity("idX") is not None
    assert os.path.exists(jpath + ".migrated")
    m.close()
    # replica open after migration resolves the segmented root
    m2 = open_synth_cache(jpath)
    assert isinstance(m2, SegmentedSynthCache)
    assert m2.get_identity("idX") is not None
    m2.close()


def test_synth_cache_quarantines_damaged_segment(tmp_path):
    from repro.core.features.synth import SegmentedSynthCache

    root = str(tmp_path / "synth.segd")
    c = SegmentedSynthCache(root, segment_records=2)
    for i in range(6):
        c.store({"k": f"id{i}", "flops": float(i), "hbm_bytes": 1.0})
    c.close()

    victim = os.path.join(root, "seg-000002.jsonl")
    with open(victim, "r+") as f:
        f.seek(10)
        f.write("????")

    c2 = SegmentedSynthCache(root, segment_records=2)
    st = c2.stats()
    assert st["quarantined_segments"] == 1
    # lost compiles are just recompiled; the rest answer warm
    assert c2.get_identity("id0") is not None
    c2.close()
