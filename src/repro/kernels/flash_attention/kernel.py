"""Pallas TPU flash-attention forward kernel.

Grid (batch*heads, q_blocks); each program streams the KV sequence in
(bk, d) tiles with the online-softmax recurrence, keeping the running
(m, l, acc) state in VMEM scratch.  Causal masking prunes nothing
structurally (the loop still visits all KV tiles — the dominant cost is
the two MXU matmuls per tile) but masks scores positionally, so the
kernel is exact for both causal and full attention.

MXU alignment: block shapes default to (bq, d) = (128, head_dim) and
bk = 128.  GQA is handled by the wrapper (ops.py) which maps each q-head
to its kv-head before the pallas_call.

Validated against ref.mha_reference with interpret=True on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, nk, bq, bk, causal, scale, q_offset):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (bq, bk)

    qb = pl.program_id(1)
    qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if causal:
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kb == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret", "q_offset")
)
def flash_attention_fwd(
    q: jnp.ndarray,   # (bh, sq, d)  — batch*heads flattened, kv pre-mapped
    k: jnp.ndarray,   # (bh, sk, d)
    v: jnp.ndarray,   # (bh, sk, d)
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    q_offset: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    nk = sk // bk
    scale = d ** -0.5
    kernel = functools.partial(
        _flash_kernel, nk=nk, bq=bq, bk=bk, causal=causal, scale=scale,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, qb, kb: (b, qb, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qb, kb: (b, kb, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qb, kb: (b, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, qb, kb: (b, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
