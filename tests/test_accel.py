"""The paper's accelerators: behavioral correctness, QoR ordering,
deployment-vs-behavioral consistency, genome plumbing."""

import numpy as np
import pytest

from repro.accel import GaussianFilter, HEVCDct, MCMAccelerator
from repro.accel.approxfpgas import circuit_level_front, restricted_library
from repro.core.acl.library import default_library

LIB = default_library()


@pytest.fixture(scope="module")
def gauss():
    return GaussianFilter()


@pytest.fixture(scope="module")
def images(gauss):
    return gauss.sample_inputs(2, seed=0)


def test_gaussian_exact_matches_reference(gauss, images):
    circuits, _ = gauss.decode(gauss.exact_genome(LIB), LIB)
    out = gauss.simulate(circuits, images)
    ref = gauss.exact_output(images)
    assert np.array_equal(out, ref)
    assert gauss.qor(circuits, images) == 100.0


def test_gaussian_exact_output_is_smoothing(gauss, images):
    ref = gauss.exact_output(images)
    inner = images[:, 1:-1, 1:-1]
    assert ref.shape == inner.shape
    assert ref.var() < inner.var()  # a Gaussian filter smooths
    assert ref.min() >= 0 and ref.max() <= 255


def test_gaussian_degrades_with_coarser_truncation(gauss, images):
    # k <= 3: beyond that the small coefficients (1,2,4) truncate to zero
    # and PSNR saturates
    psnrs = []
    for k in (1, 2, 3):
        g = gauss.exact_genome(LIB).copy()
        for i in range(9):
            g[i] = LIB.index("mul8u", f"mul8u_trunc{k}")
        circuits, _ = gauss.decode(g, LIB)
        psnrs.append(gauss.qor(circuits, images))
    assert psnrs[0] > psnrs[1] > psnrs[2]


def test_mcm_exact_and_signs():
    for row in range(4):
        m = MCMAccelerator(row)
        inp = m.sample_inputs(1, seed=1)
        circuits, _ = m.decode(m.exact_genome(LIB), LIB)
        out = m.simulate(circuits, inp)
        assert np.array_equal(out, m.exact_output(inp))


def test_hevc_exact_roundtrip():
    h = HEVCDct()
    inp = h.sample_inputs(1, seed=2)
    circuits, _ = h.decode(h.exact_genome(LIB), LIB)
    assert h.qor(circuits, inp) >= 40.0  # renorm shift loses some precision


def test_hevc_genome_has_28_slots():
    h = HEVCDct()
    assert len(h.slots) == 28
    assert len(h.mul_slot_indices()) == 16
    assert len(h.mul_slot_constants()) == 16


def test_deployment_cost_scales_with_rank(gauss):
    """XLA synthesis: higher correction rank -> more FLOPs (the cost model
    the DSE exploits)."""
    from repro.core.features.synth import synthesize_variant

    circuits, _ = gauss.decode(gauss.exact_genome(LIB), LIB)
    mit = LIB["mul8u_mitchell"]
    circuits_hi = [mit] * 9 + circuits[9:]
    lo = synthesize_variant(gauss, circuits, [0] * 9)
    hi = synthesize_variant(gauss, circuits_hi, [8] * 9)
    assert hi["flops"] > lo["flops"]
    assert hi["energy"] > lo["energy"]


def test_restricted_library_is_subset_and_pareto():
    rlib = restricted_library(LIB)
    assert len(rlib) < len(LIB)
    for kind in ("mul8u", "mul8s", "add16"):
        front = circuit_level_front(LIB, kind)
        assert any(c.is_exact for c in front)
        assert {c.name for c in rlib.kind(kind)} == {c.name for c in front}


def test_exact_genome_roundtrip(gauss):
    g = gauss.exact_genome(LIB, rank_genes=True)
    circuits, ranks = gauss.decode(g, LIB, rank_genes=True)
    assert all(c.is_exact for c in circuits)
    assert all(r == 0 for r in ranks)
    sizes = gauss.gene_sizes(LIB, rank_genes=True)
    assert len(sizes) == len(gauss.slots) + 9
    assert (g < sizes).all()
