"""Pallas TPU kernels for approximate integer matmul.

Two kernels mirror the two reference semantics in ``ref.py``:

* ``rank_k_mxu``   — the deployment path.  Per (bm, bn) output tile we
  accumulate over K-blocks: one exact MXU matmul on the dequantized
  operands plus ONE fused MXU matmul for all r correction terms, by
  packing the rank dimension into the contraction:  (bm, bk*r) @ (bk*r,
  bn).  The 256-entry U/V lookup tables live in VMEM (256*r*4 B each) and
  are gathered per tile.  fp32 accumulation in VMEM scratch.

* ``lut_matmul``   — the behavioral oracle ("DSP blocks disabled"
  analogue): every scalar product is a VMEM gather from the exhaustive
  (256,256) product table; int32 accumulation.  Not a performance path —
  it exists so the bit-exact semantics are *also* expressed as a tiled
  TPU kernel and validated against the numpy models.

Block shapes default to MXU-aligned (128, 128) tiles with bk=128.
Validated with interpret=True on CPU (tests/test_kernels.py); on real TPU
the gathers lower to VMEM dynamic-slices — acceptable for r<=8 tables.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rank_k_mxu", "lut_matmul_pallas"]


def _rank_k_kernel(xi_ref, wi_ref, u_ref, v_ref, out_ref, acc_ref, *, offset, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xi = xi_ref[...]                         # (bm, bk) int32 table indices
    wi = wi_ref[...]                         # (bk, bn) int32 table indices
    xf = (xi - offset).astype(jnp.float32)   # dequantized operand values
    wf = (wi - offset).astype(jnp.float32)
    acc = acc_ref[...] + jax.lax.dot(
        xf, wf, preferred_element_type=jnp.float32
    )

    r = u_ref.shape[1]
    if r > 0:
        bm, bk = xi.shape
        bn = wi.shape[1]
        ux = jnp.take(u_ref[...], xi.reshape(-1), axis=0)  # (bm*bk, r)
        vw = jnp.take(v_ref[...], wi.reshape(-1), axis=0)  # (bk*bn, r)
        # pack rank into the contraction: (bm, bk*r) @ (bk*r, bn)
        ux = ux.reshape(bm, bk * r)
        vw = vw.reshape(bk, bn, r).transpose(0, 2, 1).reshape(bk * r, bn)
        acc = acc + jax.lax.dot(ux, vw, preferred_element_type=jnp.float32)

    acc_ref[...] = acc

    @pl.when(k == nk - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("signed", "bm", "bn", "bk", "interpret"),
)
def rank_k_mxu(
    x: jnp.ndarray,    # (m, k) integer-valued (int32) 8-bit domain
    w: jnp.ndarray,    # (k, n)
    u: jnp.ndarray,    # (256, r) f32
    v: jnp.ndarray,    # (256, r) f32
    *,
    signed: bool = False,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    m, kdim = x.shape
    _, n = w.shape
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (m, n, kdim)
    offset = 128 if signed else 0
    xi = x.astype(jnp.int32) + offset
    wi = w.astype(jnp.int32) + offset
    nk = kdim // bk
    grid = (m // bm, n // bn, nk)
    kernel = functools.partial(_rank_k_kernel, offset=offset, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((256, u.shape[1]), lambda i, j, k: (0, 0)),
            pl.BlockSpec((256, v.shape[1]), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xi, wi, u.astype(jnp.float32), v.astype(jnp.float32))


def _lut_kernel(xi_ref, wi_ref, tab_ref, out_ref, acc_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xi = xi_ref[...]          # (bm, bk)
    wi = wi_ref[...]          # (bk, bn)
    flat = tab_ref[...].reshape(-1)
    idx = xi[:, :, None] * 256 + wi[None, :, :]       # (bm, bk, bn)
    prods = jnp.take(flat, idx.reshape(-1), axis=0).reshape(idx.shape)
    acc_ref[...] = acc_ref[...] + prods.sum(axis=1).astype(jnp.int32)

    @pl.when(k == nk - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("signed", "bm", "bn", "bk", "interpret"),
)
def lut_matmul_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    table: jnp.ndarray,   # (256, 256) int32
    *,
    signed: bool = False,
    bm: int = 64,
    bn: int = 64,
    bk: int = 64,
    interpret: bool = False,
) -> jnp.ndarray:
    m, kdim = x.shape
    _, n = w.shape
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (m, n, kdim)
    offset = 128 if signed else 0
    xi = x.astype(jnp.int32) + offset
    wi = w.astype(jnp.int32) + offset
    nk = kdim // bk
    grid = (m // bm, n // bn, nk)
    kernel = functools.partial(_lut_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((256, 256), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xi, wi, table.astype(jnp.int32))
