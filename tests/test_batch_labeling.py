"""Batched labeling engine: population sim bit-exactness vs the
per-genome loop (all registered accelerators incl. staged pipelines and
stage views), vectorized PSNR, guarded fast codegen label-invariance,
process-backend label identity, put_many, and the scheduler's
single-campaign admission-window skip."""

import os
import time

import numpy as np
import pytest

from repro.accel import GaussianFilter, HEVCDct, MCMAccelerator
from repro.accel.smoothed_dct import SmoothedDct
from repro.core import qor as qor_mod
from repro.core.acl.library import default_library
from repro.service import (
    EvalContext,
    EvalScheduler,
    InMemoryLabelStore,
    JsonlLabelStore,
)
from repro.service.store import LABEL_KEYS

LIB = default_library()

# label keys that are a pure function of (context, genome) — timing keys
# (synth_time / sim_time) legitimately differ between runs/backends
DET_KEYS = ("qor", "latency", "energy", "flops", "hbm_bytes")


def _accelerators():
    return [
        GaussianFilter(),
        MCMAccelerator(0),
        MCMAccelerator(2),
        HEVCDct(),
        SmoothedDct(),
    ] + SmoothedDct().stage_views()


def _random_genomes(accel, rng, n, rank_genes):
    sizes = accel.gene_sizes(LIB, rank_genes=rank_genes)
    g = rng.integers(0, sizes[None, :], size=(n, len(sizes)))
    g[0] = accel.exact_genome(LIB, rank_genes=rank_genes)
    return g


# ---------------------------------------------------------------------------
# population simulation == per-genome loop (bit-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rank_genes", [False, True])
def test_simulate_batch_bit_exact_all_accelerators(rank_genes):
    """Property: the vectorized population path (LUT gathers + grouped
    adders + chained per-genome intermediates) is BIT-EXACT versus
    decoding and simulating each genome independently."""
    for seed, accel in enumerate(_accelerators()):
        rng = np.random.default_rng(100 + seed)
        inputs = accel.sample_inputs(2, seed=seed)
        genomes = _random_genomes(accel, rng, 5, rank_genes)
        batch = accel.simulate_batch(
            genomes, LIB, inputs, rank_genes=rank_genes
        )
        for t, g in enumerate(genomes):
            circuits, _ = accel.decode(g, LIB, rank_genes=rank_genes)
            ref = accel.simulate(circuits, inputs)
            assert np.array_equal(batch[t], ref), (accel.name, t)


@pytest.mark.parametrize("rank_genes", [False, True])
def test_qor_batch_bit_exact_all_accelerators(rank_genes):
    for seed, accel in enumerate(_accelerators()):
        rng = np.random.default_rng(200 + seed)
        inputs = accel.sample_inputs(2, seed=seed)
        genomes = _random_genomes(accel, rng, 4, rank_genes)
        qb = accel.qor_batch(genomes, LIB, inputs, rank_genes=rank_genes)
        for t, g in enumerate(genomes):
            circuits, _ = accel.decode(g, LIB, rank_genes=rank_genes)
            assert qb[t] == accel.qor(circuits, inputs), (accel.name, t)
        # the exact anchor saturates at the PSNR cap
        assert qb[0] == qor_mod.PSNR_CAP


def test_simulate_batch_per_genome_inputs():
    """A per-genome input stack (what staged pipelines feed forward)
    matches simulating each genome on its own input."""
    accel = HEVCDct()
    rng = np.random.default_rng(7)
    genomes = _random_genomes(accel, rng, 3, False)
    stack = np.stack([accel.sample_inputs(2, seed=s) for s in range(3)])
    batch = accel.simulate_batch(
        genomes, LIB, stack, per_genome_inputs=True
    )
    for t, g in enumerate(genomes):
        circuits, _ = accel.decode(g, LIB)
        assert np.array_equal(batch[t], accel.simulate(circuits, stack[t]))


def test_split_genome_batch_matches_split_genome():
    pipe = SmoothedDct()
    rng = np.random.default_rng(3)
    for rank_genes in (False, True):
        genomes = _random_genomes(pipe, rng, 4, rank_genes)
        parts = pipe.split_genome_batch(genomes, rank_genes=rank_genes)
        for t, g in enumerate(genomes):
            for part, ref in zip(parts, pipe.split_genome(
                    g, rank_genes=rank_genes)):
                assert np.array_equal(part[t], ref)


def test_psnr_batch_matches_psnr():
    rng = np.random.default_rng(11)
    ref = rng.normal(size=(3, 16, 16)) * 40
    outs = ref[None] + rng.normal(size=(6, 3, 16, 16))
    outs[0] = ref  # exact row saturates at the cap
    vals = qor_mod.psnr_batch(ref, outs)
    assert vals[0] == qor_mod.PSNR_CAP
    for t in range(len(outs)):
        assert vals[t] == qor_mod.psnr(ref, outs[t])
    # explicit peak forwards too
    vals_p = qor_mod.psnr_batch(ref, outs, peak=100.0)
    for t in range(len(outs)):
        assert vals_p[t] == qor_mod.psnr(ref, outs[t], peak=100.0)


def test_im2col_cache_returns_same_windows():
    from repro.accel.gaussian import _IM2COL_CACHE, _im2col, _im2col_cached

    imgs = GaussianFilter().sample_inputs(2, seed=5)
    a = _im2col_cached(imgs)
    b = _im2col_cached(imgs.copy())   # same content -> cache hit
    assert a is b
    assert np.array_equal(a, _im2col(imgs))
    assert not a.flags.writeable     # cached windows are frozen
    assert len(_IM2COL_CACHE) >= 1


# ---------------------------------------------------------------------------
# label_variants rides the batched path; engine knobs stay label-invariant
# ---------------------------------------------------------------------------

def test_label_variants_qor_matches_per_genome():
    from repro.core.features import synth

    accel = MCMAccelerator(1)
    rng = np.random.default_rng(17)
    genomes = _random_genomes(accel, rng, 3, False)
    inputs = accel.sample_inputs(2, seed=synth.DEFAULT_QOR_SEED)
    labels = synth.label_variants(
        accel, genomes, LIB, qor_inputs=inputs, cache={}
    )
    for t, g in enumerate(genomes):
        circuits, _ = accel.decode(g, LIB)
        assert labels["qor"][t] == accel.qor(circuits, inputs)


def test_fast_codegen_and_lean_trace_are_label_invariant():
    """The engine's compile-side knobs (guarded fast codegen, lean
    deployment trace) must not move a single deterministic label."""
    import repro.kernels.approx_matmul.ops as ops
    from repro.core.features import synth

    accel = MCMAccelerator(2)
    rng = np.random.default_rng(23)
    genomes = _random_genomes(accel, rng, 3, False)
    fast0 = synth.FAST_CODEGEN
    try:
        synth.FAST_CODEGEN = False
        ops.LEGACY_EMBED_TABLES = True
        seed_labels = synth.label_variants(accel, genomes, LIB, cache={})
        ops.LEGACY_EMBED_TABLES = False
        synth.FAST_CODEGEN = True
        # cold engine for the second run: the shared compile cache would
        # otherwise answer from the seed run's compiles and nothing new
        # would compile (exactly the leak reset_fast_codegen exists for)
        synth.reset_fast_codegen()
        new_labels = synth.label_variants(accel, genomes, LIB, cache={})
    finally:
        synth.FAST_CODEGEN = fast0
        ops.LEGACY_EMBED_TABLES = False
    for k in DET_KEYS:
        assert np.array_equal(seed_labels[k], new_labels[k]), k
    assert synth._FAST_VERDICT.get(f"accel:{accel.name}") is not None


# ---------------------------------------------------------------------------
# stores: put_many
# ---------------------------------------------------------------------------

def test_put_many_inmemory_and_jsonl(tmp_path):
    rec = lambda v: {k: float(v) for k in LABEL_KEYS}
    mem = InMemoryLabelStore()
    mem.put_many([("a", rec(1)), ("b", rec(2))])
    assert mem.get("a") == rec(1) and mem.get("b") == rec(2)

    path = str(tmp_path / "labels.jsonl")
    store = JsonlLabelStore(path)
    store.put("a", rec(1))
    # batch: one new, one duplicate (index update only, no new line)
    store.put_many([("a", rec(1)), ("b", rec(2)), ("c", rec(3))])
    s = store.stats()
    assert s["lines"] == 3 and s["entries"] == 3
    store.close()
    again = JsonlLabelStore(path)
    assert again.get("b") == rec(2) and again.get("c") == rec(3)
    assert again.stats()["lines"] == 3
    again.close()

    empty = JsonlLabelStore(str(tmp_path / "empty.jsonl"))
    empty.put_many([])                     # no-op, no file churn
    assert empty.stats()["lines"] == 0
    empty.close()


# ---------------------------------------------------------------------------
# scheduler: single-campaign latency + process backend
# ---------------------------------------------------------------------------

class _InstantCtx:
    fingerprint = "instant"
    accel = None

    def key(self, genome):
        return "g" + "-".join(str(int(v)) for v in np.atleast_1d(genome))

    def ground_truth(self, genomes):
        genomes = np.atleast_2d(genomes)
        v = genomes.sum(axis=1).astype(float)
        return {k: v.copy() for k in LABEL_KEYS}


def test_single_campaign_skips_admission_window():
    """With one campaign pending, a batch must dispatch without eating
    the (deliberately huge) admission window."""
    sched = EvalScheduler(InMemoryLabelStore(), n_workers=1,
                          max_batch=8, max_wait_s=5.0)
    t0 = time.perf_counter()
    out = sched.label(_InstantCtx(), np.arange(8).reshape(4, 2),
                      campaign="solo")
    elapsed = time.perf_counter() - t0
    assert out["qor"].tolist() == [1.0, 5.0, 9.0, 13.0]
    assert elapsed < 2.0, f"single campaign waited {elapsed:.2f}s"
    sched.shutdown()


def test_scheduler_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        EvalScheduler(InMemoryLabelStore(), backend="gpu")


def test_process_backend_labels_identical_to_thread():
    """Process-pool labels must be byte-identical to in-process labels,
    and non-resolvable contexts must fall back transparently."""
    accel = MCMAccelerator(1)
    ctx_t = EvalContext(accel, LIB, n_qor_samples=2)
    rng = np.random.default_rng(31)
    genomes = _random_genomes(accel, rng, 3, False)

    sched_t = EvalScheduler(InMemoryLabelStore(), n_workers=1,
                            max_wait_s=0.01)
    out_t = sched_t.label(ctx_t, genomes)
    sched_t.shutdown()

    sched_p = EvalScheduler(InMemoryLabelStore(), n_workers=1,
                            max_wait_s=0.01, backend="process",
                            process_workers=1)
    out_p = sched_p.label(
        EvalContext(MCMAccelerator(1), LIB, n_qor_samples=2), genomes
    )
    for k in DET_KEYS:
        assert np.array_equal(out_t[k], out_p[k]), k
    s = sched_p.stats()
    assert s["backend"] == "process" and s["process_batches"] == 1

    # a context the worker cannot rebuild by name falls back in-process
    out_f = sched_p.label(_InstantCtx(), np.arange(4).reshape(2, 2))
    assert out_f["qor"].tolist() == [1.0, 5.0]
    assert sched_p.stats()["process_fallbacks"] == 1
    sched_p.shutdown()


def test_process_pool_can_label_gates_contexts():
    from repro.service.workers import ProcessPoolLabeler

    pool = ProcessPoolLabeler.__new__(ProcessPoolLabeler)  # no processes
    pool._lock = __import__("threading").Lock()
    pool._safe_fps = {}
    # builtin accelerator with the default library: safe
    assert pool.can_label(EvalContext(MCMAccelerator(1), LIB,
                                      n_qor_samples=2))
    # subset library changes the fingerprint: NOT safe
    sub = LIB.subset([c.name for c in LIB.circuits[:40]])
    assert not pool.can_label(EvalContext(MCMAccelerator(1), sub,
                                          n_qor_samples=2))
    # verdicts are cached per fingerprint
    assert len(pool._safe_fps) == 2
