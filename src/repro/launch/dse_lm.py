"""DSE-on-LM driver: run the paper's surrogate-guided NSGA-II exploration
over the approximate-projection space of an assigned architecture.

    PYTHONPATH=src python -m repro.launch.dse_lm --arch granite-8b \
        --n-train 48 --generations 12 --pop 32

Prints the validation PCC of the two surrogates (paper Fig. 6 analogue),
the discovered Pareto front (QoR vs energy), and per-stage timings
(paper Fig. 5 analogue).

With ``--service http://host:port`` the search runs as a campaign on a
running ``python -m repro.service`` instance instead of in this process:
the driver submits the spec, polls status, and prints the front the
service computed.  All HTTP goes through ``repro.fleet.http`` (bounded
retry + backoff), so a briefly-restarting service does not kill the
driver.  Point the service at ``--eval-backend fleet`` and the labeling
itself fans out across every registered fleet worker.
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from ..accel.lm import LMAccelerator
from ..configs import get_config
from ..core.acl.library import default_library
from ..core.dse import DSEConfig, run_dse
from ..core.nsga2 import NSGA2Config

__all__ = ["main"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--n-train", type=int, default=48)
    ap.add_argument("--generations", type=int, default=12)
    ap.add_argument("--pop", type=int, default=32)
    ap.add_argument("--parents", type=int, default=12)
    ap.add_argument("--pipeline", default="D", choices=list("BCDEF"))
    from ..core.strategies import available_strategies

    ap.add_argument("--strategy", default="nsga2",
                    choices=available_strategies(),
                    help="explorer: nsga2 (paper), bo (expected-"
                         "improvement Bayesian optimization), random, or "
                         "any registered custom strategy")
    ap.add_argument("--rank-genes", action="store_true",
                    help="beyond-paper: correction rank as a DSE axis")
    ap.add_argument("--store", default=None,
                    help="persistent JSONL label store: ground-truth labels "
                         "are reused across runs (repro.service.store)")
    ap.add_argument("--synth-cache", default=None,
                    help="persistent JSONL structural compile cache: XLA "
                         "synthesis compiles are reused across runs and "
                         "evaluation contexts (core.features.synth)")
    ap.add_argument("--eval-workers", type=int, default=2,
                    help="labeling worker threads when --store is set")
    ap.add_argument("--service", default=None, metavar="URL",
                    help="run on a campaign service instead of in-process: "
                         "submit the spec to this base URL (python -m "
                         "repro.service; with --eval-backend fleet the "
                         "labels come from the whole fleet)")
    ap.add_argument("--timeout", type=float, default=3600.0,
                    help="seconds to wait for the remote campaign "
                         "(--service only)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.service:
        return _run_on_service(args)

    accel = LMAccelerator(get_config(args.arch), seed=args.seed)
    lib = default_library()
    cfg = DSEConfig(
        pipeline=args.pipeline,
        strategy=args.strategy,
        n_train=args.n_train,
        n_qor_samples=2,
        rank_genes=args.rank_genes,
        nsga=NSGA2Config(
            pop_size=args.pop, n_parents=args.parents,
            n_generations=args.generations, seed=args.seed,
        ),
        seed=args.seed,
    )

    if args.synth_cache:
        from ..core.features import synth

        cache = synth.open_synth_cache(args.synth_cache)
        synth.set_shared_synth_cache(cache)
        print(f"[dse-lm] synth cache {args.synth_cache}: "
              f"{len(cache)} compiled structures")

    labeler = scheduler = None
    if args.store:
        from ..service.scheduler import EvalScheduler
        from ..service.store import EvalContext, open_label_store

        store = open_label_store(args.store)
        scheduler = EvalScheduler(store, n_workers=args.eval_workers)
        ctx = EvalContext(accel, lib, rank_genes=args.rank_genes,
                          n_qor_samples=cfg.n_qor_samples)
        print(f"[dse-lm] label store {args.store}: {len(store)} entries")

        def labeler(genomes):
            return scheduler.label(ctx, genomes)

    res = run_dse(accel, lib, cfg, labeler=labeler, verbose=True)
    if scheduler is not None:
        s = scheduler.stats()
        print(f"[dse-lm] labeling: {s['requests']} requests, "
              f"{s['store_hits']} store hits, {s['labeled']} synthesized "
              f"(hit rate {s['label_hit_rate']:.0%})")
        scheduler.shutdown()

    print(f"\n[dse-lm] {accel.name} (strategy={args.strategy})")
    print(f"  surrogate validation PCC: "
          + ", ".join(f"{k}={v:.3f}" for k, v in res.val_pcc.items()))
    print(f"  timings: " + ", ".join(
        f"{k}={v:.1f}s" for k, v in res.timings.items()))
    # search.genomes already includes the stage-1 training sample
    print(f"  surrogate evaluations: {res.search.n_evaluated} "
          f"(vs {len(res.search.genomes)} synth calls)")
    front = res.front_objectives
    order = np.argsort(front[:, 0])
    print(f"  Pareto front ({len(front)} designs)  [PSNR dB, energy J]:")
    for i in order[:12]:
        g = res.front_genomes[i]
        circuits, _ = accel.decode(g, lib, rank_genes=args.rank_genes)
        names = {s.name: c.name for s, c in zip(accel.slots, circuits)
                 if not c.is_exact}
        print(f"    psnr={-front[i,0]:7.2f}  energy={front[i,1]:.3e}  {names}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump({
                "arch": args.arch,
                "val_pcc": res.val_pcc,
                "timings": res.timings,
                "front": front.tolist(),
                "front_genomes": res.front_genomes.tolist(),
            }, f, indent=1)


def _run_on_service(args) -> None:
    """Submit the spec as a campaign on a running service and report its
    result — the remote twin of the in-process path above."""
    from ..service.api import Client

    cli = Client(args.service)
    cid = cli.submit(
        accel=f"lm:{args.arch}",
        strategy=args.strategy,
        pipeline=args.pipeline,
        n_train=args.n_train,
        n_qor_samples=2,
        rank_genes=args.rank_genes,
        pop_size=args.pop,
        n_parents=args.parents,
        n_generations=args.generations,
        seed=args.seed,
    )
    print(f"[dse-lm] campaign {cid} submitted to {args.service}")
    st = cli.wait(cid, timeout=args.timeout)
    if st["state"] != "done":
        raise SystemExit(f"[dse-lm] campaign {cid} ended {st['state']}: "
                         f"{st.get('error') or 'timeout'}")
    res = cli.result(cid)
    front = np.asarray(res["front"], dtype=float)
    print(f"\n[dse-lm] lm:{args.arch} (strategy={args.strategy}, remote)")
    if res.get("val_pcc"):
        print("  surrogate validation PCC: "
              + ", ".join(f"{k}={v:.3f}" for k, v in res["val_pcc"].items()))
    order = np.argsort(front[:, 0])
    print(f"  Pareto front ({len(front)} designs)  [PSNR dB, energy J]:")
    for i in order[:12]:
        print(f"    psnr={-front[i, 0]:7.2f}  energy={front[i, 1]:.3e}")
    if args.out:
        detail = cli.front(cid)
        with open(args.out, "w") as f:
            json.dump({
                "arch": args.arch,
                "campaign": cid,
                "service": args.service,
                "val_pcc": res.get("val_pcc"),
                "front": front.tolist(),
                "front_genomes": detail["genomes"],
            }, f, indent=1)


if __name__ == "__main__":
    main()
