"""Serving tier: FrontCatalog tiers + SLA selector edge cases, the
continuous-batching ServingEngine (grouping, measured QoR, hot-swap
atomicity + version pinning under concurrent traffic), the manager's
front-update subscription, and POST /serve over HTTP."""

import threading
import time

import numpy as np
import pytest

from repro.core.acl.library import default_library
from repro.serving import (
    EmptyFrontError,
    FrontCatalog,
    NoFrontError,
    OperatingPoint,
    ServingEngine,
)
from repro.service.campaigns import CampaignManager, CampaignSpec, make_accelerator

SMALL = dict(n_train=10, n_qor_samples=2, pop_size=8, n_parents=4,
             n_generations=2)


def _cat(rows, accel="toy", objectives=("qor", "energy"), **kw):
    """rows: [(genome tuple, qor, energy)] with RAW qor (higher better);
    builds via from_front, so qor goes through the stored minimization
    convention (negated) and back."""
    genomes = [list(g) for g, _, _ in rows]
    front = [[-q, e] for _, q, e in rows]
    return FrontCatalog.from_front(accel, genomes, front, objectives, **kw)


# ---------------------------------------------------------------------------
# catalog: construction, tiers, signs
# ---------------------------------------------------------------------------

def test_front_sign_convention_roundtrip():
    cat = _cat([((0, 1), 80.0, 5.0), ((2, 3), 40.0, 2.0)])
    # labels are raw: qor back to higher-is-better
    assert cat.points[0].labels == {"qor": 80.0, "energy": 5.0}
    d = cat.to_json()
    # the emitted front rows are minimization-convention again
    assert d["front"][0] == [-80.0, 5.0]
    again = FrontCatalog.from_json(d)
    assert [p.labels for p in again.points] == [p.labels for p in cat.points]
    assert again.digest == cat.digest


def test_tiers_exact_balanced_budget():
    cat = _cat([
        ((0,), 95.0, 10.0),   # best qor -> exact
        ((1,), 70.0, 4.0),    # knee -> balanced
        ((2,), 40.0, 3.5),    # cheapest -> budget
    ])
    assert cat.tiers["exact"] == 0
    assert cat.points[cat.tiers["budget"]].labels["energy"] == 3.5
    assert cat.points[cat.tiers["balanced"]].labels["qor"] == 70.0


def test_empty_front_raises():
    cat = FrontCatalog("toy", [])
    assert cat.empty and len(cat) == 0 and cat.tiers == {}
    with pytest.raises(EmptyFrontError):
        cat.select(tier="exact")
    # an empty /front payload builds an empty catalog (not a shape error)
    empty = FrontCatalog.from_front("toy", [], [])
    assert empty.empty


def test_single_point_front_everything_maps_to_it():
    cat = _cat([((3, 1), 60.0, 4.0)])
    for tier in ("exact", "balanced", "budget"):
        sel = cat.select(tier=tier)
        assert sel.index == 0 and sel.point.genome == (3, 1)
    ok = cat.select(budget={"energy": 10.0})
    assert ok.feasible and ok.index == 0
    degraded = cat.select(budget={"energy": 1.0})
    assert not degraded.feasible and degraded.index == 0


def test_selector_validation():
    cat = _cat([((0,), 50.0, 1.0)])
    with pytest.raises(ValueError, match="not both"):
        cat.select(tier="exact", budget={"energy": 1.0})
    with pytest.raises(ValueError, match="unknown tier"):
        cat.select(tier="turbo")
    with pytest.raises(ValueError, match="unknown budget objective"):
        cat.select(budget={"latency": 1.0})
    with pytest.raises(ValueError, match="empty"):
        cat.select(budget={})
    # default is the balanced tier
    assert cat.select().tier == "balanced"


def test_budget_semantics_qor_is_lower_bound():
    cat = _cat([((0,), 90.0, 9.0), ((1,), 50.0, 3.0)])
    # qor >= 80 forces the expensive point even though it costs more
    sel = cat.select(budget={"qor": 80.0})
    assert sel.feasible and sel.point.labels["qor"] == 90.0
    # energy <= 5 forces the cheap point
    sel = cat.select(budget={"energy": 5.0})
    assert sel.feasible and sel.point.labels["energy"] == 3.0
    # jointly infeasible -> nearest-feasible degrade, deterministic
    sel = cat.select(budget={"qor": 80.0, "energy": 5.0})
    assert not sel.feasible
    sel2 = cat.select(budget={"qor": 80.0, "energy": 5.0})
    assert sel.index == sel2.index


def test_infeasible_degrades_to_minimal_violation():
    cat = _cat([((0,), 90.0, 9.0), ((1,), 70.0, 5.0), ((2,), 30.0, 1.0)])
    # energy <= 0.5: every point violates; (2,) violates least
    sel = cat.select(budget={"energy": 0.5})
    assert not sel.feasible and sel.point.genome == (2,)
    # qor >= 99: (0,) violates least
    sel = cat.select(budget={"qor": 99.0})
    assert not sel.feasible and sel.point.genome == (0,)


def test_deterministic_tie_breaking_on_identical_labels():
    # two genomes with identical objectives: canonical order ties on
    # genome bytes, so (1, 9) beats (2, 0) everywhere, every time
    rows = [((2, 0), 60.0, 4.0), ((1, 9), 60.0, 4.0)]
    for perm in (rows, rows[::-1]):
        cat = _cat(perm)
        assert cat.points[0].genome == (1, 9)
        assert cat.select(tier="exact").point.genome == (1, 9)
        assert cat.select(budget={"energy": 5.0}).point.genome == (1, 9)
        assert cat.select(budget={"energy": 0.1}).point.genome == (1, 9)


def test_missing_objective_label_rejected():
    with pytest.raises(ValueError, match="lacks objective"):
        FrontCatalog("toy", [OperatingPoint((0,), {"qor": 1.0})],
                     ("qor", "energy"))
    with pytest.raises(ValueError, match="columns"):
        FrontCatalog.from_front("toy", [[0]], [[1.0]], ("qor", "energy"))


# ---------------------------------------------------------------------------
# engine: batching, measured QoR, hot-swap
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gauss():
    accel = make_accelerator("gaussian3x3")
    lib = default_library()
    g_exact = accel.exact_genome(lib)
    g_cheap = g_exact.copy()
    # a genuinely approximate variant: non-exact circuit in every slot
    for i in range(9):
        g_cheap[i] = (g_cheap[i] + 1) % len(lib.kind("mul8u"))
    return accel, lib, g_exact, g_cheap


def _gauss_cat(accel, g_exact, g_cheap, qor_cheap=40.0):
    return _cat([
        (tuple(int(v) for v in g_exact), 100.0, 10.0),
        (tuple(int(v) for v in g_cheap), qor_cheap, 3.0),
    ], accel=accel.name)


def test_engine_serves_tiers_with_measured_qor(gauss):
    accel, lib, g_exact, g_cheap = gauss
    eng = ServingEngine(accel, lib,
                        catalog=_gauss_cat(accel, g_exact, g_cheap))
    try:
        X = accel.sample_inputs(2, seed=0)
        r_exact = eng.serve(X, tier="exact")
        r_budget = eng.serve(X, tier="budget")
        # exact genome reproduces the exact output: capped PSNR
        assert r_exact["qor"] == pytest.approx(100.0)
        assert r_exact["genome"] == [int(v) for v in g_exact]
        # the approximate point's MEASURED qor is finite and lower
        assert r_budget["qor"] < r_exact["qor"]
        assert r_budget["genome"] == [int(v) for v in g_cheap]
        st = eng.stats()
        assert st["responses"] == 2 and st["errors"] == 0
        assert st["catalog"]["points"] == 2
    finally:
        eng.close()


def test_engine_batches_same_point_into_one_group(gauss):
    accel, lib, g_exact, g_cheap = gauss
    eng = ServingEngine(accel, lib, max_batch=8, max_wait_s=0.2,
                        catalog=_gauss_cat(accel, g_exact, g_cheap))
    try:
        X = accel.sample_inputs(2, seed=1)
        futs = [eng.submit(X, tier="budget") for _ in range(4)]
        results = [f.result(timeout=120) for f in futs]
        assert {r["batch"] for r in results} == {results[0]["batch"]}
        assert all(r["group_size"] == 4 for r in results)
        assert eng.stats()["groups"] == 1
    finally:
        eng.close()


def test_engine_coerces_wire_float_inputs(gauss):
    """JSON payloads arrive float64; integer-operand accelerators must
    serve integral floats identically to native ints and reject
    non-integral values with a clean ValueError (HTTP 400), not a deep
    gather IndexError."""
    accel, lib, g_exact, g_cheap = gauss
    eng = ServingEngine(accel, lib,
                        catalog=_gauss_cat(accel, g_exact, g_cheap))
    try:
        X = accel.sample_inputs(2, seed=7)
        as_int = eng.serve(X, tier="budget", return_outputs=True)
        as_float = eng.serve(X.astype(np.float64), tier="budget",
                             return_outputs=True)
        assert as_float["qor"] == as_int["qor"]
        assert as_float["outputs"] == as_int["outputs"]
        with pytest.raises(ValueError, match="integer operands"):
            eng.serve(X + 0.5, tier="budget")
    finally:
        eng.close()


def test_engine_error_isolation(gauss):
    accel, lib, g_exact, g_cheap = gauss
    eng = ServingEngine(accel, lib,
                        catalog=_gauss_cat(accel, g_exact, g_cheap))
    try:
        X = accel.sample_inputs(1, seed=2)
        bad = eng.submit(X, tier="turbo")
        pinned = eng.submit(X, tier="exact", pin_version=999)
        good = eng.submit(X, tier="exact")
        with pytest.raises(ValueError, match="unknown tier"):
            bad.result(timeout=120)
        with pytest.raises(ValueError, match="unknown catalog version"):
            pinned.result(timeout=120)
        assert good.result(timeout=120)["qor"] == pytest.approx(100.0)
    finally:
        eng.close()


def test_hot_swap_and_version_pinning_byte_identical(gauss):
    accel, lib, g_exact, g_cheap = gauss
    cat1 = _gauss_cat(accel, g_exact, g_cheap, qor_cheap=40.0)
    eng = ServingEngine(accel, lib, catalog=cat1)
    try:
        X = accel.sample_inputs(2, seed=3)
        before = eng.serve(X, tier="budget", return_outputs=True)
        assert before["catalog_version"] == 1

        # the "improved" front drops the cheap point: budget moves
        cat2 = _cat([(tuple(int(v) for v in g_exact), 100.0, 10.0)],
                    accel=accel.name)
        assert eng.install(cat2) == 2
        # reinstalling identical content is a no-op (digest match)
        assert eng.install(_cat(
            [(tuple(int(v) for v in g_exact), 100.0, 10.0)],
            accel=accel.name)) is None

        after = eng.serve(X, tier="budget", return_outputs=True)
        assert after["catalog_version"] == 2
        assert after["genome"] == [int(v) for v in g_exact]

        # requests pinned to v1 still serve the OLD genome with
        # byte-identical outputs
        pinned = eng.serve(X, tier="budget", pin_version=1,
                           return_outputs=True)
        assert pinned["catalog_version"] == 1
        assert pinned["genome"] == before["genome"]
        assert np.array_equal(np.asarray(pinned["outputs"]),
                              np.asarray(before["outputs"]))
        assert eng.stats()["hot_swaps"] == 1
    finally:
        eng.close()


def test_hot_swap_atomicity_under_concurrent_traffic(gauss):
    """Swap catalogs while requests are in flight: every response must
    be internally consistent (its genome matches its reported catalog
    version) and none may error or hang."""
    accel, lib, g_exact, g_cheap = gauss
    cat1 = _gauss_cat(accel, g_exact, g_cheap)
    eng = ServingEngine(accel, lib, catalog=cat1, max_batch=4,
                        max_wait_s=0.002)
    version_genome = {1: [int(v) for v in g_cheap]}
    try:
        X = accel.sample_inputs(1, seed=4)
        stop = threading.Event()
        futs = []

        def swapper():
            flip = 0
            while not stop.is_set():
                flip += 1
                # alternate which point is cheapest so the budget tier
                # flips genome with each successful install
                q = 40.0 if flip % 2 else 100.0
                cat = _cat([
                    (tuple(int(v) for v in g_exact), 100.0,
                     10.0 if flip % 2 else 3.0),
                    (tuple(int(v) for v in g_cheap), q, 3.0
                     if flip % 2 else 10.0),
                ], accel=accel.name)
                v = eng.install(cat)
                if v is not None:
                    budget_i = cat.tiers["budget"]
                    version_genome[v] = list(cat.points[budget_i].genome)
                time.sleep(0.001)

        sw = threading.Thread(target=swapper)
        sw.start()
        for _ in range(40):
            futs.append(eng.submit(X, tier="budget"))
        results = [f.result(timeout=180) for f in futs]
        stop.set()
        sw.join(timeout=10)
        for r in results:
            assert r["genome"] == version_genome[r["catalog_version"]], r
        st = eng.stats()
        assert st["errors"] == 0 and st["responses"] == 40
        assert st["hot_swaps"] >= 1
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# manager integration + HTTP
# ---------------------------------------------------------------------------

def test_manager_front_subscription_and_serving_flow():
    mgr = CampaignManager()
    fired = []
    mgr.subscribe_front(fired.append)
    try:
        cid = mgr.submit(CampaignSpec(accel="mcm2", **SMALL))
        assert mgr.wait(cid, timeout=300) == "done"
        assert "mcm2" in fired

        with pytest.raises(NoFrontError):
            mgr.serving.engine_for("mcm1")

        eng = mgr.serving.engine_for("mcm2")
        accel = make_accelerator("mcm2")
        X = accel.sample_inputs(4, seed=1)
        r = eng.serve(X, tier="exact")
        assert r["accel"] == "mcm2" and np.isfinite(r["qor"])
        # the engine served off the manager's merged global front
        gf = mgr.global_front("mcm2", ("qor", "energy"))
        assert len(eng.catalog) == len(gf["genomes"])

        # a second completed campaign fires the subscription again and
        # the hub refreshes the engine (same front -> same version)
        v_before = eng.catalog.version
        cid2 = mgr.submit(CampaignSpec(accel="mcm2", **SMALL))
        assert mgr.wait(cid2, timeout=300) == "done"
        assert fired.count("mcm2") >= 2
        assert eng.catalog.version >= v_before

        stats = mgr.stats()
        assert "mcm2" in stats["serving"]["engines"]
        assert mgr.serving_stats()["engines"]["mcm2"]["responses"] >= 1
    finally:
        mgr.shutdown()


def test_http_serve_endpoint():
    from repro.service.api import Client, make_server

    mgr = CampaignManager()
    srv = make_server(mgr, port=0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    cli = Client(f"http://127.0.0.1:{srv.server_address[1]}")
    try:
        # serving before any front exists is a 409 conflict
        with pytest.raises(Exception, match="409"):
            cli.serve("mcm2", [[1, 2]], tier="exact")

        cid = cli.submit(accel="mcm2", **SMALL)
        assert cli.wait(cid, timeout=300)["state"] == "done"

        accel = make_accelerator("mcm2")
        X = accel.sample_inputs(4, seed=2)
        r = cli.serve("mcm2", X, tier="budget")
        assert r["tier"] == "budget" and r["catalog_version"] == 1
        assert np.isfinite(r["qor"]) and r["group_size"] >= 1
        r2 = cli.serve("mcm2", X,
                       budget={"energy": r["labels"]["energy"] + 1.0})
        assert r2["feasible"]

        # malformed SLAs and payloads are 400s
        with pytest.raises(Exception, match="400"):
            cli.serve("mcm2", X, tier="turbo")
        with pytest.raises(Exception, match="400"):
            cli._req("/serve", {"accel": "mcm2"})  # missing inputs
        with pytest.raises(Exception, match="400"):
            cli._req("/serve", {"inputs": [[1]]})  # missing accel
        with pytest.raises(Exception, match="400"):
            cli.serve("mcm2", X, tier="exact", budget={"energy": 1.0})
        # omitting both tier and budget defaults to the balanced tier
        assert cli.serve("mcm2", X)["tier"] == "balanced"

        ss = cli.serving_stats()
        assert ss["engines"]["mcm2"]["responses"] >= 2
        assert ss["engines"]["mcm2"]["catalog"]["tiers"].keys() == {
            "exact", "balanced", "budget"}
        met = cli.metrics()
        assert "repro_serving_requests_total" in met
        assert "repro_serving_queue_depth" in met
    finally:
        srv.shutdown()
        mgr.shutdown()


# ---------------------------------------------------------------------------
# LM bridge: genome -> policy
# ---------------------------------------------------------------------------

def test_lm_policy_for_genome():
    accel = make_accelerator("lm:falcon-mamba-7b")
    lib = default_library()
    g = accel.exact_genome(lib)
    # exact genome -> no approximated classes
    assert accel.policy_for_genome(g, lib).assignments == {}
    g2 = g.copy()
    g2[0] = (g2[0] + 1) % len(lib.kind("mul8s"))
    pol = accel.policy_for_genome(g2, lib)
    assert len(pol.assignments) == 1
    with pytest.raises(ValueError, match="genes"):
        accel.policy_for_genome(g[:-1], lib)
    # the serving backend dispatch keys off this method
    from repro.serving import LMBackend, make_backend

    assert isinstance(make_backend(accel, lib), LMBackend)


# ---------------------------------------------------------------------------
# graceful degradation: backpressure, deadlines, injected backend faults
# ---------------------------------------------------------------------------

def test_bounded_queue_rejects_with_retriable_overload(gauss):
    from repro import faults
    from repro.faults import FaultPlan
    from repro.serving.engine import OverloadedError

    accel, lib, g_exact, g_cheap = gauss
    # stall the backend so the queue actually fills
    faults.install(FaultPlan().add("serving.backend", "latency",
                                   delay_s=0.3))
    eng = ServingEngine(accel, lib, max_batch=1, max_wait_s=0.0,
                        max_queue=2,
                        catalog=_gauss_cat(accel, g_exact, g_cheap))
    try:
        X = accel.sample_inputs(1, seed=0)
        admitted = []
        rejected = 0
        for _ in range(12):
            try:
                admitted.append(eng.submit(X, tier="exact"))
            except OverloadedError as exc:
                assert exc.retriable and "retry" in str(exc)
                rejected += 1
        assert rejected > 0                     # the bound bites
        faults.uninstall()
        for f in admitted:                      # admitted work completes
            assert f.result(timeout=120)["qor"] == pytest.approx(100.0)
        st = eng.stats()
        assert st["rejects"] == rejected
        assert st["responses"] == len(admitted)
    finally:
        faults.uninstall()
        eng.close()


def test_deadline_expired_request_dropped_not_run(gauss):
    from repro import faults
    from repro.faults import FaultPlan
    from repro.serving.engine import DeadlineExceeded

    accel, lib, g_exact, g_cheap = gauss
    faults.install(FaultPlan().add("serving.backend", "latency",
                                   delay_s=0.4, times=1))
    eng = ServingEngine(accel, lib, max_batch=1, max_wait_s=0.0,
                        catalog=_gauss_cat(accel, g_exact, g_cheap))
    try:
        X = accel.sample_inputs(1, seed=0)
        # first request stalls the batcher; the second's deadline
        # elapses while it waits and it is dropped, not executed
        slow = eng.submit(X, tier="exact")
        doomed = eng.submit(X, tier="exact", deadline_s=0.05)
        assert slow.result(timeout=120)["qor"] == pytest.approx(100.0)
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=120)
        assert eng.stats()["expired"] == 1
    finally:
        faults.uninstall()
        eng.close()


def test_injected_backend_error_isolated_per_group(gauss):
    from repro import faults
    from repro.faults import FaultInjected, FaultPlan

    accel, lib, g_exact, g_cheap = gauss
    faults.install(FaultPlan().add("serving.backend", "error", times=1))
    eng = ServingEngine(accel, lib, max_batch=1, max_wait_s=0.0,
                        catalog=_gauss_cat(accel, g_exact, g_cheap))
    try:
        X = accel.sample_inputs(1, seed=0)
        with pytest.raises(FaultInjected):
            eng.serve(X, tier="exact", timeout=120)
        # the engine survives: the next request serves normally
        assert eng.serve(X, tier="exact",
                         timeout=120)["qor"] == pytest.approx(100.0)
        assert eng.stats()["errors"] == 1
    finally:
        faults.uninstall()
        eng.close()


def test_http_serve_maps_overload_to_429(gauss):
    from repro import faults
    from repro.faults import FaultPlan
    from repro.fleet.http import HttpError
    from repro.service.api import Client, make_server

    mgr = CampaignManager(eval_workers=1, campaign_workers=1,
                          serving=dict(max_batch=1, max_wait_s=0.0,
                                       max_queue=1))
    srv = make_server(mgr, port=0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    try:
        cid = mgr.submit(CampaignSpec(accel="gaussian3x3", **SMALL))
        assert mgr.wait(cid, timeout=600) == "done"
        cli = Client(f"http://127.0.0.1:{srv.server_address[1]}")
        X = make_accelerator("gaussian3x3").sample_inputs(1, seed=0)
        assert "qor" in cli.serve("gaussian3x3", X, tier="exact")
        faults.install(FaultPlan().add("serving.backend", "latency",
                                       delay_s=0.5))
        # saturate the 1-deep queue, then expect a 429 (no retries so
        # the rejection surfaces instead of being waited out)
        saw_429 = False
        futs = []
        from concurrent.futures import ThreadPoolExecutor

        def one():
            return cli._req("/serve", {"accel": "gaussian3x3",
                                       "inputs": X.tolist(),
                                       "tier": "exact"})

        with ThreadPoolExecutor(8) as pool:
            futs = [pool.submit(one) for _ in range(8)]
            for f in futs:
                try:
                    f.result()
                except HttpError as exc:
                    if exc.code == 429:
                        saw_429 = True
        assert saw_429
    finally:
        faults.uninstall()
        srv.shutdown()
        mgr.shutdown()
