"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave (attn at
position 4 of each 8-layer block), MoE 16e top-2 every other layer
[arXiv:2403.19887]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    n_experts=16, n_experts_active=2, moe_period=2,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    attn_period=8, attn_offset=4,
    moment_dtype="bfloat16", param_dtype="bfloat16",
    # 398B: FSDP across pods too (512-way weight sharding) — intra-pod
    # FSDP alone leaves 12.4 GB/chip of optimizer+param state
    sharding_overrides=(("embed", ("data", "pod")),),
    notes="398B params: bf16 master weights + bf16 moments (stochastic-"
          "rounding regime) + cross-pod FSDP to fit 16 GB/chip.",
)
