"""Mixture-of-experts layer: top-k token-choice routing with GShard-style
capacity dispatch (einsum form — expert-parallel shardable: the experts
dimension lives on the "model" mesh axis, XLA inserts the all-to-alls).

Returns the load-balance auxiliary loss (Switch/GShard form) so the train
loop can add it to the objective.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain
from .approx_linear import ApproxPolicy, linear
from .common import ParamSpec, act_fn, rms_norm
from .config import ModelConfig

__all__ = ["moe_param_specs", "moe_layer", "dense_mlp_param_specs", "dense_mlp"]


def dense_mlp_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "norm": ParamSpec((d,), ("norm",), init="zeros"),
        "wi": ParamSpec((d, f), ("embed", "mlp")),
        "wg": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }


def dense_mlp(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    policy: Optional[ApproxPolicy] = None,
) -> jnp.ndarray:
    h = rms_norm(x, p["norm"], cfg.rms_eps)
    up = linear(h, p["wi"], "ffn_in", policy)
    gate = act_fn(cfg.mlp_act)(linear(h, p["wg"], "ffn_in", policy))
    up = constrain(up * gate, ("batch", "seq", "act_mlp"))
    return linear(up, p["wo"], "ffn_out", policy)


def moe_param_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.padded_experts
    return {
        "norm": ParamSpec((d,), ("norm",), init="zeros"),
        "router": ParamSpec((d, e), ("embed", None)),
        "wi": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wg": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed")),
    }


MOE_GROUP = 4096  # max tokens per routing group; see moe_layer docstring


def set_moe_group(n: int) -> None:
    """Perf knob (§Perf): GShard dispatch capacity scales with the
    routing-group length, so the (b, s, e, cap) one-hots grow
    QUADRATICALLY with sequence length if the whole sequence is one
    group.  Grouping bounds cap at group*k/e*cf regardless of s."""
    global MOE_GROUP
    MOE_GROUP = int(n)


def moe_layer(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,               # (b, s, d)
    cfg: ModelConfig,
    *,
    policy: Optional[ApproxPolicy] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out, aux_loss)."""
    b0, s0, d = x.shape
    if MOE_GROUP and s0 > MOE_GROUP and s0 % MOE_GROUP == 0:
        # sequence grouping: route/dispatch in fixed-size groups
        g = s0 // MOE_GROUP
        x = x.reshape(b0 * g, MOE_GROUP, d)
    b, s, d = x.shape
    e = cfg.padded_experts
    k = cfg.n_experts_active
    cap = max(int(s * k / e * cfg.capacity_factor), 1)
    h = rms_norm(x, p["norm"], cfg.rms_eps)

    logits = jnp.einsum(
        "bsd,de->bse", h.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    # mask padded experts out of routing
    if e > cfg.n_experts:
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)                   # (b, s, e)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (b, s, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # GShard capacity dispatch: rank of each (token, expert) assignment
    sel = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)      # (b, s, k, e)
    # priority: k-th choices ranked after all (k-1)-th choices
    flat = sel.transpose(0, 2, 1, 3).reshape(b, k * s, e)
    rank_in_expert = jnp.cumsum(flat, axis=1) - flat          # (b, k*s, e)
    rank = rank_in_expert.reshape(b, k, s, e).transpose(0, 2, 1, 3)
    keep = (rank < cap) * sel                                 # (b, s, k, e)
    # an expert is selected at most once per token, so the k axis can be
    # summed BEFORE the capacity one-hot — avoids a (b,s,k,e,cap) 5-D
    # intermediate (memory hog at scale)
    pos_e = (rank * keep).sum(axis=2).astype(jnp.int32)       # (b, s, e)
    keep_e = keep.sum(axis=2)                                 # (b, s, e)
    gate_e = (gate_vals[..., None] * sel).sum(axis=2)         # (b, s, e)

    cap_oh = jax.nn.one_hot(pos_e, cap, dtype=jnp.float32) * keep_e[..., None]
    dispatch = cap_oh                                          # (b, s, e, cap)
    combine = cap_oh * gate_e[..., None]

    dispatch = constrain(dispatch, ("batch", "seq", "act_experts", None))
    xin = jnp.einsum(
        "bsec,bsd->ebcd", dispatch.astype(x.dtype), h
    )                                                          # (e, b, cap, d)
    xin = constrain(xin, ("act_experts", "batch", None, None))

    def expert_ffn(xin):
        up = jnp.einsum(
            "ebcd,edf->ebcf", xin.astype(jnp.bfloat16), p["wi"].astype(jnp.bfloat16)
        )
        gate = act_fn(cfg.mlp_act)(
            jnp.einsum(
                "ebcd,edf->ebcf",
                xin.astype(jnp.bfloat16),
                p["wg"].astype(jnp.bfloat16),
            )
        )
        return jnp.einsum(
            "ebcf,efd->ebcd", up * gate, p["wo"].astype(jnp.bfloat16)
        )

    hout = expert_ffn(xin)                                     # (e, b, cap, d)
    hout = constrain(hout, ("act_experts", "batch", None, None))
    out = jnp.einsum("bsec,ebcd->bsd", combine.astype(x.dtype), hout)

    # Switch-style load-balance aux loss over the real experts
    me = probs[..., : cfg.n_experts].mean(axis=(0, 1))
    ce = (
        sel[..., : cfg.n_experts].sum(axis=2).mean(axis=(0, 1))
        * cfg.n_experts / k
    )
    aux = cfg.n_experts * jnp.sum(me * ce)
    if (b, s) != (b0, s0):
        out = out.reshape(b0, s0, d)
    return out, aux.astype(jnp.float32)
