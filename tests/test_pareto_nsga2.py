"""Pareto utilities + NSGA-II: unit and property tests."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.nsga2 import NSGA2Config, nsga2
from repro.core.pareto import (
    crowding_distance,
    dominates,
    fast_non_dominated_sort,
    hypervolume_2d,
    non_dominated_mask,
    pareto_front,
)

obj_arrays = hnp.arrays(
    np.float64, st.tuples(st.integers(1, 40), st.integers(2, 4)),
    elements=st.floats(-10, 10, allow_nan=False),
)


def _brute_mask(obj):
    n = len(obj)
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        for j in range(n):
            if i != j and dominates(obj[j], obj[i]):
                mask[i] = False
                break
    return mask


@given(obj_arrays)
@settings(max_examples=100, deadline=None)
def test_non_dominated_mask_matches_bruteforce(obj):
    assert np.array_equal(non_dominated_mask(obj), _brute_mask(obj))


@given(obj_arrays)
@settings(max_examples=50, deadline=None)
def test_fronts_partition_and_order(obj):
    fronts = fast_non_dominated_sort(obj)
    idx = np.concatenate(fronts)
    assert sorted(idx.tolist()) == list(range(len(obj)))
    # front 0 == the non-dominated set
    assert set(fronts[0].tolist()) == set(np.flatnonzero(_brute_mask(obj)))
    # no point in front k is dominated by a point in front k+1
    for a, b in zip(fronts[:-1], fronts[1:]):
        for i in a:
            for j in b:
                assert not dominates(obj[j], obj[i])


def test_crowding_boundaries_infinite():
    obj = np.array([[0.0, 3.0], [1.0, 2.0], [2.0, 1.0], [3.0, 0.0]])
    cd = crowding_distance(obj)
    assert np.isinf(cd[0]) and np.isinf(cd[3])
    assert np.isfinite(cd[1]) and np.isfinite(cd[2])


def test_hypervolume_known_case():
    obj = np.array([[1.0, 2.0], [2.0, 1.0]])
    # ref (3,3): union of two 1x... boxes: (3-1)(3-2) + (3-2)(3-1) - overlap (3-2)(3-2)
    assert hypervolume_2d(obj, (3, 3)) == pytest.approx(3.0)


def test_hypervolume_monotone_in_points():
    rng = np.random.default_rng(0)
    obj = rng.random((20, 2))
    hv1 = hypervolume_2d(obj[:10], (2, 2))
    hv2 = hypervolume_2d(obj, (2, 2))
    assert hv2 >= hv1 - 1e-12


# ---------------------------------------------------------------------------
# NSGA-II behaviour
# ---------------------------------------------------------------------------

def _zdt1_like(genomes):
    """Discretized ZDT1: gene 0 = f1 position, rest control g."""
    x = genomes.astype(np.float64)
    f1 = x[:, 0] / 31.0
    g = 1.0 + 9.0 * x[:, 1:].mean(axis=1) / 31.0
    f2 = g * (1.0 - np.sqrt(f1 / g))
    return np.stack([f1, f2], axis=1)


def test_nsga2_converges_toward_zdt1_front():
    gene_sizes = [32] * 8
    res = nsga2(
        gene_sizes, _zdt1_like,
        NSGA2Config(pop_size=48, n_parents=16, n_generations=30, seed=1),
    )
    # on the true front g == 1 (all non-position genes zero)
    front = res.front_objectives
    g_vals = front[:, 1] / (1.0 - np.sqrt(front[:, 0]) + 1e-12)
    assert np.median(g_vals) < 1.5  # random search median ~5.5
    # returned front is mutually non-dominated
    assert non_dominated_mask(front).all()


def test_nsga2_elitism_never_loses_best():
    def evaluate(g):
        s = g.sum(axis=1, dtype=np.float64)
        return np.stack([s, -s + g[:, 0]], axis=1)

    res = nsga2([8] * 4, evaluate,
                NSGA2Config(pop_size=20, n_parents=8, n_generations=10, seed=0))
    best_per_gen = [log.objectives[:, 0].min() for log in res.history]
    overall = res.objectives[:, 0].min()
    assert overall <= min(best_per_gen) + 1e-12


def test_nsga2_deterministic():
    r1 = nsga2([5] * 3, _zdt1_like,
               NSGA2Config(pop_size=16, n_parents=8, n_generations=5, seed=7))
    r2 = nsga2([5] * 3, _zdt1_like,
               NSGA2Config(pop_size=16, n_parents=8, n_generations=5, seed=7))
    assert np.array_equal(r1.genomes, r2.genomes)


def test_nsga2_dedup_reduces_evaluations():
    calls = {"n": 0}

    def evaluate(g):
        calls["n"] += len(g)
        return _zdt1_like(g)

    res = nsga2([3] * 2, evaluate,
                NSGA2Config(pop_size=40, n_parents=10, n_generations=5, seed=0))
    # only 9 distinct genomes exist
    assert calls["n"] <= 9
    assert res.n_evaluated == calls["n"]


def test_population_sizes_conserved():
    res = nsga2([6] * 4, _zdt1_like,
                NSGA2Config(pop_size=24, n_parents=10, n_generations=4, seed=3))
    assert res.genomes.shape == (10, 4)
    for log in res.history:
        assert log.genomes.shape == (24, 4)
