"""Campaign-service quickstart: the paper's DSE as a service call.

    PYTHONPATH=src python examples/service_quickstart.py

Submits two concurrent campaigns for the HEVC MCM2 accelerator to an
in-process CampaignManager backed by a persistent label store, then
re-submits one against the warm store.  Watch the label accounting: the
second concurrent campaign rides the first's in-flight synthesis, and
the warm rerun performs zero ground-truth labeling.

Set REPRO_SMOKE=1 for the CI-sized fast mode."""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.service import CampaignManager, CampaignSpec, JsonlLabelStore

SMOKE = bool(os.environ.get("REPRO_SMOKE"))


def main():
    store_path = os.path.join(tempfile.mkdtemp(prefix="svc_demo_"),
                              "labels.jsonl")
    spec = CampaignSpec(accel="mcm2",
                        n_train=10 if SMOKE else 48, n_qor_samples=2,
                        pop_size=8 if SMOKE else 16,
                        n_parents=4 if SMOKE else 8,
                        n_generations=2 if SMOKE else 4)

    print(f"label store: {store_path}")
    store = JsonlLabelStore(store_path)
    mgr = CampaignManager(store, eval_workers=2, campaign_workers=2)

    print("\n-- two concurrent campaigns (identical spec) --")
    c1, c2 = mgr.submit(spec), mgr.submit(spec)
    mgr.wait(c1), mgr.wait(c2)
    r1, r2 = mgr.result(c1), mgr.result(c2)
    s = mgr.scheduler.stats()
    print(f"requests={s['requests']}  synthesized={s['labeled']}  "
          f"in-flight dedup={s['inflight_dedup_hits']}  "
          f"coalesced batches={s['coalesced_batches']}/{s['batches']}")
    print(f"fronts identical: "
          f"{np.array_equal(r1.front_objectives, r2.front_objectives)}")

    print("\n-- warm rerun (fresh manager, same store file) --")
    mgr.shutdown(); store.close()
    store2 = JsonlLabelStore(store_path)
    mgr2 = CampaignManager(store2, eval_workers=2)
    c3 = mgr2.submit(spec)
    mgr2.wait(c3)
    s2 = mgr2.scheduler.stats()
    print(f"requests={s2['requests']}  synthesized={s2['labeled']}  "
          f"store hits={s2['store_hits']} (hit rate "
          f"{s2['label_hit_rate']:.0%})")

    front = mgr2.result(c3).front_objectives
    print(f"\ntrue Pareto front ({len(front)} designs, PSNR dB vs energy J):")
    for i in np.argsort(front[:, 0])[:8]:
        print(f"  psnr={-front[i, 0]:7.2f}  energy={front[i, 1]:.3e}")
    mgr2.shutdown()


if __name__ == "__main__":
    main()
