"""Kernel microbenchmarks: approximate-matmul deployment paths and
attention implementations (CPU wall time; the derived column carries the
TPU-relevant structural quantity)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.acl.library import default_library
from repro.kernels.approx_matmul import approx_matmul, from_circuit
from repro.kernels.flash_attention import attention

from .common import emit, time_fn


def run(seed: int = 0):
    lib = default_library()
    rng = np.random.default_rng(seed)
    m = k = n = 256
    x = jnp.asarray(rng.integers(-128, 128, (m, k)))
    w = jnp.asarray(rng.integers(-128, 128, (k, n)))

    for name in ("mul8s_exact", "mul8s_trunc4", "mul8s_mitchell",
                 "mul8s_drum4"):
        c = lib[name]
        spec = from_circuit(c)

        def mxu():
            approx_matmul(x, w, spec).block_until_ready()

        us = time_fn(mxu, repeat=3)
        emit(f"kernels.approx_matmul.{name}.mxu", us,
             f"cost_factor={c.deploy_cost_factor():.2f}")

    c = lib["mul8s_trunc2"]
    spec = from_circuit(c)

    def lut():
        approx_matmul(x[:64, :64], w[:64, :64], spec, path="lut").block_until_ready()

    emit("kernels.approx_matmul.lut_behavioral_64", time_fn(lut, repeat=3),
         "oracle")

    b, h, s, d = 1, 4, 512, 64
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    for impl, chunk in (("naive", 0), ("chunked", 128)):
        def attn():
            attention(q, kk, v, causal=True, impl=impl,
                      chunk=chunk or s).block_until_ready()

        # naive materializes the s^2 score tensor; chunked caps it at
        # s*chunk — the structural memory ratio is the derived column
        ratio = s / (chunk or s)
        emit(f"kernels.attention.{impl}", time_fn(attn, repeat=3),
             f"score_mem_ratio={ratio:.0f}x")
