from .base import RANK_CHOICES, Accelerator, Slot
from .gaussian import GaussianFilter
from .hevc_dct import HEVCDct, MCMAccelerator

__all__ = [
    "Accelerator", "Slot", "RANK_CHOICES",
    "GaussianFilter", "HEVCDct", "MCMAccelerator", "SmoothedDct",
]


def __getattr__(name):
    # lazy: smoothed_dct subclasses repro.hierarchy.StagedPipeline, which
    # itself imports accel.base — a top-level import here would turn that
    # into a cycle whenever repro.hierarchy is imported first
    if name == "SmoothedDct":
        from .smoothed_dct import SmoothedDct

        return SmoothedDct
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
