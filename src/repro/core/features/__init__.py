from . import cheap, pipelines, synth
from .cheap import circuit_features_cheap, variant_features
from .pipelines import PIPELINES, build_extractor, evaluate_pipeline
from .synth import circuit_features_synth, label_variants, synthesize_variant

__all__ = [
    "cheap", "synth", "pipelines",
    "circuit_features_cheap", "variant_features",
    "circuit_features_synth", "label_variants", "synthesize_variant",
    "PIPELINES", "build_extractor", "evaluate_pipeline",
]
