"""Feature pipelines (A-F), surrogate training, and the end-to-end DSE —
including the paper's qualitative claims at reduced scale."""

import numpy as np
import pytest

from repro.accel import MCMAccelerator
from repro.core.acl.library import default_library
from repro.core.dse import DSEConfig, run_dse
from repro.core.features import synth
from repro.core.features.cheap import circuit_features_cheap, variant_features
from repro.core.features.pipelines import build_extractor, evaluate_pipeline
from repro.core.nsga2 import NSGA2Config
from repro.core.pareto import non_dominated_mask
from repro.core.surrogates import pcc

LIB = default_library()


@pytest.fixture(scope="module")
def mcm():
    return MCMAccelerator(0)


@pytest.fixture(scope="module")
def labeled(mcm):
    rng = np.random.default_rng(0)
    sizes = mcm.gene_sizes(LIB)
    genomes = rng.integers(0, sizes[None, :], size=(60, len(sizes)))
    labels = synth.label_variants(mcm, genomes, LIB, cache={})
    return genomes, labels


def test_cheap_features_shapes(mcm):
    from repro.core.features.cheap import CHEAP_AC_DIM

    for c in LIB.circuits[:5]:
        f = circuit_features_cheap(c)
        assert f.shape == (CHEAP_AC_DIM,) and np.isfinite(f).all()
    rng = np.random.default_rng(1)
    sizes = mcm.gene_sizes(LIB)
    genomes = rng.integers(0, sizes[None, :], size=(7, len(sizes)))
    X = variant_features(mcm, genomes, LIB)
    assert X.shape[0] == 7 and np.isfinite(X).all()


@pytest.mark.parametrize("pipeline", ["C", "D", "F"])
def test_extractors_run(pipeline, mcm):
    ext = build_extractor(pipeline, mcm, LIB)
    rng = np.random.default_rng(2)
    sizes = mcm.gene_sizes(LIB)
    genomes = rng.integers(0, sizes[None, :], size=(5, len(sizes)))
    X = ext(genomes)
    assert X.shape[0] == 5 and np.isfinite(X).all()
    assert ext.per_variant_time >= 0


def test_pipeline_D_beats_F_and_is_fast(mcm, labeled):
    """Paper Fig. 5 qualitative claims: accelerator-level features (D)
    correlate better than AC-composition-free (F); both are orders of
    magnitude cheaper per variant than synthesis."""
    genomes, labels = labeled
    tr, te = slice(0, 40), slice(40, None)
    ltr = {k: v[tr] for k, v in labels.items()}
    lte = {k: v[te] for k, v in labels.items()}
    rep_d = evaluate_pipeline("D", mcm, LIB, genomes[tr], ltr, genomes[te], lte)
    rep_f = evaluate_pipeline("F", mcm, LIB, genomes[tr], ltr, genomes[te], lte)
    assert rep_d.pcc_hw >= rep_f.pcc_hw - 0.05
    assert rep_d.pcc_hw > 0.6
    synth_time = labels["synth_time"][labels["synth_time"] > 0].mean()
    assert rep_d.per_variant_time < synth_time / 10


def test_dse_end_to_end_beats_exact_only_energy(mcm):
    cfg = DSEConfig(
        n_train=30, n_qor_samples=2,
        nsga=NSGA2Config(pop_size=16, n_parents=8, n_generations=3, seed=0),
    )
    res = run_dse(mcm, LIB, cfg)
    assert res.front_mask.any()
    assert non_dominated_mask(res.front_objectives).all()
    assert res.val_pcc["energy"] > 0.4
    # the front contains at least one non-exact (cheaper) design
    assert (res.front_objectives[:, 1] <
            res.final_labels["energy"].max() + 1e-12).any()
    # timings recorded for every stage
    assert set(res.timings) == {"label", "train", "explore", "final_eval"}


def test_surrogate_evaluations_cheaper_than_synthesis(mcm):
    """The paper's central claim: exploration touches far more variants
    than synthesis does."""
    cfg = DSEConfig(
        n_train=20, n_qor_samples=2,
        nsga=NSGA2Config(pop_size=24, n_parents=8, n_generations=4, seed=1),
    )
    res = run_dse(mcm, LIB, cfg)
    n_synth = cfg.n_train + len(res.search.genomes)
    assert res.search.n_evaluated > 0
    assert res.timings["explore"] < res.timings["label"]
