from .kernel import flash_attention_fwd
from .ops import attention
from .ref import chunked_attention, mha_reference, repeat_kv

__all__ = [
    "attention", "flash_attention_fwd",
    "chunked_attention", "mha_reference", "repeat_kv",
]
