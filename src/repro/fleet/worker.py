"""Remote labeling worker: ``python -m repro.fleet.worker``.

One worker process joins a fleet orchestrator over HTTP, pulls leased
genome chunks, labels them with the SAME batched ground-truth path every
other backend uses, and streams the results back:

    PYTHONPATH=src python -m repro.fleet.worker \\
        --orchestrator http://127.0.0.1:8177 \\
        --store runs/service_labels.jsonl \\
        --synth-cache runs/service_synth.jsonl

Warm start: pointing the worker at the shared ``JsonlLabelStore`` /
``JsonlSynthCache`` files means a joining worker answers already-labeled
genomes from the store replica without recomputing, and never recompiles
a deployment-graph structure any fleet member (or the service itself)
has compiled before.  Both are optional — a storeless worker simply
computes everything.

Safety: every leased chunk carries the parent's evaluation-context
fingerprint.  The worker rebuilds the context from the descriptor and
REJECTS the lease on any mismatch (the PR-3 gate), so a drifted worker
can never poison the fleet's labels.  Heartbeats run on a daemon thread;
a ``kill -9`` simply stops them, and the orchestrator requeues the
in-flight lease after expiry — zero labels lost.
"""

from __future__ import annotations

import argparse
import os
import socket

import threading
import time
from typing import Dict, Optional

import numpy as np

from .. import faults, obs
from .http import CircuitBreaker, HttpError, request_json
from .protocol import PROTOCOL_VERSION, build_context, encode_labels

__all__ = ["FleetWorker", "main"]


class FleetWorker:
    """The worker loop: register -> poll leases -> label -> stream back,
    with a heartbeat thread keeping the registration alive."""

    def __init__(
        self,
        orchestrator: str,
        *,
        worker_id: Optional[str] = None,
        accels: Optional[list] = None,
        store_path: Optional[str] = None,
        synth_cache_path: Optional[str] = None,
        warm: bool = True,
        request_timeout_s: float = 30.0,
        verbose: bool = False,
    ):
        self.base = orchestrator.rstrip("/")
        self.worker_id = worker_id
        self.accels = list(accels) if accels else ["*"]
        self.store_path = store_path
        self.synth_cache_path = synth_cache_path
        self.warm = warm
        self.request_timeout_s = float(request_timeout_s)
        self.verbose = verbose
        # graceful degradation on the worker's one HTTP edge: fail fast
        # while the orchestrator is down (breaker) and never let one
        # call outlive a couple of lease TTLs (total deadline)
        self._breaker = CircuitBreaker(
            threshold=8, reset_s=5.0, name="worker")
        self._post_deadline_s = max(4 * self.request_timeout_s, 60.0)
        self._stop = threading.Event()
        self._hb_thread: Optional[threading.Thread] = None
        self._heartbeat_s = 5.0
        self._idle_wait_s = 0.25
        self._library = None
        self._store = None
        self._ctxs: Dict[str, object] = {}      # fingerprint -> EvalContext
        self._verified_fps: set = set()
        self._fps_advertised: set = set()
        # counters (reported with results / heartbeats)
        reg = obs.REGISTRY
        self.n_leases = reg.counter(
            "repro_worker_leases_total", "leases served by this worker")
        self.n_labels = reg.counter(
            "repro_worker_labels_total", "genomes labeled by this worker")
        self.n_store_hits = reg.counter(
            "repro_worker_store_hits_total",
            "leased genomes answered from the shared store replica")
        self.n_rejects = reg.counter(
            "repro_worker_rejects_total",
            "leases rejected on fingerprint drift")
        self._logger = obs.get_logger("repro.fleet.worker")
        if verbose:
            obs.setup_logging("info")

    # ------------------------------------------------------------------
    def _log(self, msg: str) -> None:
        self._logger.info("%s", msg)

    def _post(self, path: str, payload: Dict, *, retries: int = 4) -> Dict:
        return request_json(self.base + path, payload,
                            timeout=self.request_timeout_s, retries=retries,
                            breaker=self._breaker,
                            total_deadline_s=self._post_deadline_s)

    def _init_engine(self) -> None:
        """One-time per-process warmup, exactly the process-pool worker
        recipe: shared persistent compile cache first (before any
        compile), then the library and its per-circuit label caches."""
        from ..core.acl.library import default_library
        from ..core.features import synth

        if self.synth_cache_path:
            # open_synth_cache resolves the path to whatever tier the
            # service uses (segmented root or legacy jsonl) WITHOUT
            # migrating — the service owns migration
            synth.set_shared_synth_cache(
                synth.open_synth_cache(self.synth_cache_path))
        self._library = default_library()
        if self.warm:
            from ..service.workers import warm_library

            warm_library(self._library)
        if self.store_path:
            from ..service.store import open_label_store

            # read-only replica of the shared store: leased genomes that
            # already have labels are answered without recomputing (the
            # orchestrator commits results, so the worker never appends)
            self._store = open_label_store(self.store_path)

    def register(self) -> str:
        resp = self._post("/fleet/register", {
            "protocol": PROTOCOL_VERSION,
            "worker": self.worker_id,
            "host": socket.gethostname(),
            "pid": os.getpid(),
            "accels": self.accels,
            "fingerprints": sorted(self._verified_fps),
        })
        if not resp.get("ok"):
            raise RuntimeError(f"registration rejected: {resp.get('error')}")
        self.worker_id = resp["worker"]
        self._heartbeat_s = float(resp.get("heartbeat_s", 5.0))
        self._idle_wait_s = float(resp.get("idle_wait_s", 0.25))
        self._fps_advertised = set(self._verified_fps)
        self._log(f"registered (heartbeat every {self._heartbeat_s:.1f}s)")
        return self.worker_id

    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(self._heartbeat_s):
            try:
                f = faults.check("fleet.heartbeat", worker=self.worker_id)
                if f is not None:
                    if f.delay_s > 0:
                        time.sleep(f.delay_s)
                    if f.kind in ("drop", "error"):
                        continue  # beat lost in flight; TTL clock runs
                fresh = self._verified_fps - self._fps_advertised
                resp = self._post("/fleet/heartbeat", {
                    "worker": self.worker_id,
                    "fingerprints": sorted(fresh),
                }, retries=1)
                if resp.get("reregister"):
                    self.register()
                else:
                    self._fps_advertised |= fresh
            except Exception:  # noqa: BLE001 - next beat retries
                pass

    # ------------------------------------------------------------------
    def _context(self, desc: Dict):
        fp = desc["fingerprint"]
        ctx = self._ctxs.get(fp)
        if ctx is None:
            ctx = build_context(desc, library=self._library)
            self._ctxs[fp] = ctx
            self._verified_fps.add(fp)
        return ctx

    def _label_chunk(self, ctx, genomes: np.ndarray):
        """Warm-start from the shared store, ground-truth the misses."""
        from ..service.store import LABEL_KEYS

        hits = {}
        if self._store is not None:
            self._store.refresh()
            for i, g in enumerate(genomes):
                rec = self._store.get(ctx.key(g))
                if rec is not None:
                    hits[i] = rec
        miss_idx = [i for i in range(len(genomes)) if i not in hits]
        if miss_idx:
            fresh = ctx.ground_truth(genomes[np.asarray(miss_idx)])
        out = {k: np.empty(len(genomes), dtype=np.float64)
               for k in LABEL_KEYS}
        for k in LABEL_KEYS:
            for i, rec in hits.items():
                out[k][i] = float(rec[k])
            for j, i in enumerate(miss_idx):
                out[k][i] = float(np.asarray(fresh[k])[j])
        return out, len(hits)

    def step(self) -> bool:
        """One poll: lease, label, stream back.  Returns True when a
        lease was served (False = idle poll)."""
        resp = self._post("/fleet/lease", {"worker": self.worker_id})
        if resp.get("reregister"):
            self.register()
            return False
        lease = resp.get("lease")
        if not lease:
            self._stop.wait(float(resp.get("idle_wait_s",
                                           self._idle_wait_s)))
            return False
        lid = lease["id"]
        genomes = np.asarray(lease["genomes"], dtype=np.int64)
        # adopt the lease's trace context: spans recorded here carry the
        # campaign/batch ids minted on the orchestrator side, and ride
        # back on the result payload for the orchestrator to ingest
        rec = obs.recorder()
        rec.clear()
        with obs.attach(lease.get("trace"), worker=self.worker_id,
                        lease=lid):
            try:
                ctx = self._context(lease["ctx"])
            except Exception as exc:  # noqa: BLE001 - drift/unknown name
                self.n_rejects.inc()
                with obs.span("worker.reject", lease=lid):
                    pass
                self._log(f"rejecting lease {lid}: {exc}")
                self._post("/fleet/result", {
                    "worker": self.worker_id, "lease": lid,
                    "reject": True, "error": str(exc),
                    "spans": rec.snapshot(),
                })
                rec.clear()
                return True
            t0 = time.perf_counter()
            with obs.span("worker.serve", n=int(len(genomes))) as sp:
                labels, store_hits = self._label_chunk(ctx, genomes)
                sp.set(store_hits=store_hits)
            busy = time.perf_counter() - t0
        self.n_leases.inc()
        self.n_labels.inc(len(genomes))
        self.n_store_hits.inc(store_hits)
        self._post("/fleet/result", {
            "worker": self.worker_id,
            "lease": lid,
            "labels": encode_labels(labels),
            "store_hits": store_hits,
            "busy_s": busy,
            "spans": rec.snapshot(),
        })
        rec.clear()
        self._log(f"lease {lid}: {len(genomes)} labels "
                  f"({store_hits} store hits) in {busy:.2f}s")
        return True

    def run(self, *, max_leases: Optional[int] = None,
            max_idle_s: Optional[float] = None) -> None:
        """Register and serve until stopped (or ``max_leases`` chunks /
        ``max_idle_s`` of continuous idleness, for tests and drivers)."""
        self._init_engine()
        self.register()
        self._hb_thread = threading.Thread(
            target=self._heartbeat_loop, name="fleet-heartbeat", daemon=True)
        self._hb_thread.start()
        idle_since = time.monotonic()
        try:
            while not self._stop.is_set():
                if self.step():
                    idle_since = time.monotonic()
                    if (max_leases is not None
                            and self.n_leases.value >= max_leases):
                        return
                elif (max_idle_s is not None
                      and time.monotonic() - idle_since > max_idle_s):
                    return
        except HttpError as exc:
            # orchestrator gone for longer than the retry budget: exit
            # loudly — the supervisor (or the user) restarts us
            self._log(f"orchestrator unreachable, exiting: {exc}")
            raise
        finally:
            self._stop.set()
            try:
                # polite leave: lets the orchestrator requeue anything we
                # held without waiting out the heartbeat TTL.  Best
                # effort — a kill -9 skips this and the TTL path covers it
                self._post("/fleet/heartbeat",
                           {"worker": self.worker_id, "bye": True},
                           retries=0)
            except Exception:  # noqa: BLE001 - dying anyway
                pass

    def stop(self) -> None:
        self._stop.set()


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet.worker",
        description="Remote ground-truth labeling worker: registers with "
                    "a fleet orchestrator, pulls leased genome chunks, "
                    "streams labels back with heartbeats",
    )
    ap.add_argument("--orchestrator", required=True,
                    help="orchestrator base URL, e.g. http://host:8177 "
                         "(the campaign service with --eval-backend fleet, "
                         "or a standalone serve_fleet listener)")
    ap.add_argument("--id", default=None,
                    help="stable worker id (default: generated; reusing an "
                         "id after a crash rejoins as the same worker)")
    ap.add_argument("--accels", default="*",
                    help="comma-separated accelerator names this worker "
                         "serves ('*' = any builtin)")
    ap.add_argument("--store", default=None,
                    help="shared JSONL label store to warm-start from "
                         "(read-only replica)")
    ap.add_argument("--synth-cache", default=None,
                    help="shared persistent structural compile cache")
    ap.add_argument("--no-warm", action="store_true",
                    help="skip the per-circuit table/SVD warmup (faster "
                         "start, slower first chunks)")
    ap.add_argument("--max-leases", type=int, default=None,
                    help="exit after serving N chunks (benchmarks/tests)")
    ap.add_argument("--max-idle-s", type=float, default=None,
                    help="exit after this long with no work")
    ap.add_argument("--log-level", default=None,
                    choices=["debug", "info", "warning", "error"],
                    help="structured log level (worker/campaign ids in "
                         "every record; default: warning, or info with "
                         "--verbose)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="also sink this worker's spans to a local JSONL "
                         "file (spans always ride back to the "
                         "orchestrator on result payloads)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    obs.setup_logging(args.log_level
                      or ("info" if args.verbose else "warning"))
    if args.trace:
        obs.set_sink(args.trace)
    worker = FleetWorker(
        args.orchestrator,
        worker_id=args.id,
        accels=[a.strip() for a in args.accels.split(",") if a.strip()],
        store_path=args.store,
        synth_cache_path=args.synth_cache,
        warm=not args.no_warm,
        verbose=args.verbose,
    )
    worker.run(max_leases=args.max_leases, max_idle_s=args.max_idle_s)


if __name__ == "__main__":
    main()
