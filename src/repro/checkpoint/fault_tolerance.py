"""Fault-tolerant training-loop harness.

``run_resilient`` drives a step function with:
  * periodic checkpointing (ckpt.save, atomic),
  * automatic restart-from-latest on failure (any exception from the step
    fn, or injected via ``FailureInjector`` in tests),
  * a bounded restart budget,
  * straggler mitigation by construction: the data pipeline is
    counter-based (data/pipeline.py), so a restarted/resized job replays
    step k's exact global batch with no data-loader state.

Elastic resize: because checkpoints are host-staged npy + manifest and
restore() takes target shardings, the same checkpoint restores onto a
different mesh (tests/test_checkpoint.py exercises 8-device -> 4-device).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from . import ckpt

__all__ = ["FailureInjector", "run_resilient"]


class FailureInjector:
    """Deterministically raise at the given step numbers (once each)."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclass
class RunReport:
    steps_run: int = 0
    restarts: int = 0
    checkpoints: int = 0
    wall_time: float = 0.0
    history: list = field(default_factory=list)


def run_resilient(
    init_state_fn: Callable[[], Any],
    step_fn: Callable[[Any, int], tuple],     # (state, step) -> (state, metrics)
    *,
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    max_restarts: int = 10,
    injector: Optional[FailureInjector] = None,
    verbose: bool = False,
) -> tuple:
    """Returns (final_state, RunReport)."""
    report = RunReport()
    t0 = time.perf_counter()
    restarts = 0
    while True:
        try:
            latest = ckpt.latest_step(ckpt_dir)
            if latest is not None:
                state = ckpt.restore(ckpt_dir, latest, init_state_fn())
                start = latest
                if verbose:
                    print(f"[ft] restored step {latest}")
            else:
                state = init_state_fn()
                start = 0
            for step in range(start, n_steps):
                if injector is not None:
                    injector.maybe_fail(step)
                state, metrics = step_fn(state, step)
                report.steps_run += 1
                report.history.append((step, metrics))
                if (step + 1) % ckpt_every == 0 or step + 1 == n_steps:
                    ckpt.save(ckpt_dir, step + 1, state)
                    report.checkpoints += 1
            break
        except Exception as e:  # noqa: BLE001 — restart on any step failure
            restarts += 1
            report.restarts = restarts
            if restarts > max_restarts:
                raise RuntimeError(
                    f"exceeded restart budget ({max_restarts})"
                ) from e
            if verbose:
                print(f"[ft] failure: {e}; restarting ({restarts})")
    report.wall_time = time.perf_counter() - t0
    return state, report
