"""Hierarchical multi-stage search (paper §V, the scalability strategy).

Multi-stage applications (pre-filter -> transform pipelines, chained
kernels) make the flat DSE genome the *product* of the stage spaces.
This package implements the paper's hierarchical decomposition on top of
the PR-1 campaign service:

  * ``staged``   — ``StagedPipeline``: N stage accelerators composed into
                   one ``Accelerator`` (chained behavioral sim, chained
                   MXU deployment, per-stage re-quantization couplings),
                   plus ``StageView``: one stage exposed as a standalone
                   accelerator whose QoR is measured in situ (all other
                   stages exact),
  * ``compose``  — per-stage Pareto fronts composed into application
                   candidates with incremental non-dominated pruning (the
                   cross-product is never fully materialized),
  * ``search``   — ``run_hierarchical``: one concurrent DSE campaign per
                   stage through the ``CampaignManager`` (shared label
                   store), composition, then end-to-end re-labeling of
                   only the surviving candidates.
"""

from .staged import Coupling, StagedPipeline, StageView
from .compose import ComposeResult, StageFront, compose_fronts, truncate_front
from .search import HierarchicalConfig, HierarchicalResult, run_hierarchical

__all__ = [
    "Coupling",
    "StagedPipeline",
    "StageView",
    "StageFront",
    "ComposeResult",
    "compose_fronts",
    "truncate_front",
    "HierarchicalConfig",
    "HierarchicalResult",
    "run_hierarchical",
]
