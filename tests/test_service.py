"""Campaign service: label store persistence (incl. cross-process),
scheduler hit/miss accounting + in-flight dedup, campaign concurrency
with seed-identical results, and the run_dse labeler injection seam."""

import os
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from repro.accel import MCMAccelerator
from repro.core.acl.library import default_library
from repro.core.dse import DSEConfig, label_unique, run_dse
from repro.core.nsga2 import NSGA2Config
from repro.service import (
    CampaignManager,
    CampaignSpec,
    EvalContext,
    EvalScheduler,
    InMemoryLabelStore,
    JsonlLabelStore,
)
from repro.service.store import LABEL_KEYS

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SMALL = dict(n_train=10, n_qor_samples=2, pop_size=8, n_parents=4,
             n_generations=2)


def small_cfg(seed=0):
    return DSEConfig(
        n_train=SMALL["n_train"], n_qor_samples=SMALL["n_qor_samples"],
        nsga=NSGA2Config(pop_size=SMALL["pop_size"],
                         n_parents=SMALL["n_parents"],
                         n_generations=SMALL["n_generations"], seed=seed),
        seed=seed,
    )


@pytest.fixture
def ctx():
    return EvalContext(MCMAccelerator(1), default_library(), n_qor_samples=2)


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------

def test_store_roundtrip_and_hit_accounting(tmp_path):
    store = JsonlLabelStore(str(tmp_path / "labels.jsonl"))
    rec = {k: float(i) for i, k in enumerate(LABEL_KEYS)}
    assert store.get("k1") is None            # miss
    store.put("k1", rec)
    assert store.get("k1") == rec             # hit
    s = store.stats()
    assert s["hits"] == 1 and s["misses"] == 1 and s["entries"] == 1
    store.close()

    # a fresh instance (same path) replays the file: persistence
    again = JsonlLabelStore(str(tmp_path / "labels.jsonl"))
    assert again.get("k1") == rec
    assert len(again) == 1


def test_store_persists_across_processes(tmp_path, ctx):
    """A child process writes labels; the parent store reads them."""
    path = str(tmp_path / "labels.jsonl")
    code = textwrap.dedent(f"""
        from repro.accel import MCMAccelerator
        from repro.core.acl.library import default_library
        from repro.service import EvalContext, JsonlLabelStore
        import numpy as np
        ctx = EvalContext(MCMAccelerator(1), default_library(), n_qor_samples=2)
        store = JsonlLabelStore({path!r})
        g = ctx.accel.exact_genome(ctx.library)
        labels = ctx.ground_truth(g[None, :])
        store.put(ctx.key(g), {{k: labels[k][0] for k in labels}})
        store.close()
        print("WROTE", ctx.key(g))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": SRC}, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    key = out.stdout.split("WROTE ")[1].strip()

    store = JsonlLabelStore(path)
    # same context in this process derives the same key (content address)
    g = ctx.accel.exact_genome(ctx.library)
    assert ctx.key(g) == key
    rec = store.get(key)
    assert rec is not None and rec["qor"] > 0


def test_store_compaction(tmp_path):
    """compact() rewrites the log one line per unique key; replay of the
    compacted file is O(unique labels)."""
    import json as _json

    path = str(tmp_path / "labels.jsonl")
    rec = {k: float(i) for i, k in enumerate(LABEL_KEYS)}
    store = JsonlLabelStore(path)
    store.put("k1", rec)
    store.put("k2", rec)
    store.close()
    # duplicates, as left by concurrent writers in other processes
    with open(path, "a") as f:
        for _ in range(3):
            f.write(_json.dumps({"k": "k1", "l": rec, "t": 0.0}) + "\n")
        f.write("not json\n")

    s2 = JsonlLabelStore(path)
    st = s2.stats()
    assert st["lines"] == 6 and st["entries"] == 2
    assert s2.compact() == 4                 # 3 dups + 1 malformed dropped
    assert s2.stats()["lines"] == 2
    assert s2.get("k1") == rec and s2.get("k2") == rec
    with open(path) as f:
        assert len(f.readlines()) == 2
    # appends still work after the rewrite, and a fresh replay sees all
    s2.put("k3", rec)
    s2.close()
    s3 = JsonlLabelStore(path)
    assert len(s3) == 3 and s3.stats()["lines"] == 3
    s3.close()


def test_store_refresh_does_not_recount_own_writes(tmp_path):
    """A store's own appends must not be re-replayed (and re-counted) by
    refresh(), or auto-compaction would fire on duplicate-free files."""
    import json as _json

    path = str(tmp_path / "labels.jsonl")
    rec = {k: 1.0 for k in LABEL_KEYS}
    store = JsonlLabelStore(path, auto_compact_ratio=2.0)
    for i in range(5):
        store.put(f"k{i}", rec)
    store.refresh()
    s = store.stats()
    assert s["lines"] == 5 and s["entries"] == 5
    assert store.compactions == 0       # no spurious auto-compaction
    assert store.compact() == 0         # nothing to drop
    # a foreign append (another process) is still picked up
    with open(path, "a") as f:
        f.write(_json.dumps({"k": "kx", "l": rec, "t": 0.0}) + "\n")
    store.refresh()
    assert store.get("kx") == rec and store.stats()["lines"] == 6
    store.close()


def test_store_auto_compact(tmp_path):
    """Opt-in threshold: replaying a file with > ratio x duplicate lines
    triggers compaction automatically."""
    import json as _json

    path = str(tmp_path / "labels.jsonl")
    rec = {k: 1.0 for k in LABEL_KEYS}
    with open(path, "w") as f:
        for _ in range(10):
            f.write(_json.dumps({"k": "k1", "l": rec, "t": 0.0}) + "\n")

    store = JsonlLabelStore(path, auto_compact_ratio=2.0)
    assert store.compactions == 1
    assert store.stats()["lines"] == 1 and len(store) == 1
    with open(path) as f:
        assert len(f.readlines()) == 1
    store.close()

    # without the opt-in, the file is left as-is
    with open(path, "a") as f:
        for _ in range(10):
            f.write(_json.dumps({"k": "k1", "l": rec, "t": 0.0}) + "\n")
    plain = JsonlLabelStore(path)
    assert plain.compactions == 0 and plain.stats()["lines"] == 11
    plain.close()

    with pytest.raises(ValueError):
        JsonlLabelStore(path, auto_compact_ratio=0.5)


def test_store_compact_races_concurrent_writer_processes(tmp_path):
    """Regression (fleet satellite): compact() racing concurrent
    appender PROCESSES must not drop records.  Before the cross-process
    write lock, the compaction's read-rewrite-rename could miss a torn
    tail another writer was mid-append on (or strand its next appends in
    the dropped inode).  Two subprocess writers append disjoint key
    ranges while the parent compacts in a loop; every key must survive
    in the final file."""
    path = str(tmp_path / "labels.jsonl")
    rec = {k: 1.0 for k in LABEL_KEYS}
    n_keys, n_writers = 120, 2

    writer = textwrap.dedent("""
        import sys
        from repro.service import JsonlLabelStore
        from repro.service.store import LABEL_KEYS
        path, wid, n = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
        rec = {k: 1.0 for k in LABEL_KEYS}
        store = JsonlLabelStore(path)
        for i in range(n):
            store.put(f"w{wid}-k{i}", rec)
        store.close()
        print("DONE", wid)
    """)
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", writer, path, str(w), str(n_keys)],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env={**os.environ, "PYTHONPATH": SRC},
        )
        for w in range(n_writers)
    ]
    compactor = JsonlLabelStore(path)
    deadline = time.time() + 300
    while any(p.poll() is None for p in procs) and time.time() < deadline:
        compactor.compact()
        time.sleep(0.002)
    for p in procs:
        out, err = p.communicate(timeout=60)
        assert p.returncode == 0, err[-2000:]
        assert "DONE" in out
    compactor.refresh()
    expected = {f"w{w}-k{i}" for w in range(n_writers) for i in range(n_keys)}
    assert {k for k in expected if compactor.get(k) is not None} == expected
    # a final compaction leaves exactly one line per key on disk
    compactor.compact()
    with open(path) as f:
        lines = f.readlines()
    assert len(lines) == len(expected)
    compactor.close()

    fresh = JsonlLabelStore(path)
    assert len(fresh) == len(expected)
    fresh.close()


def test_context_fingerprint_sensitivity(ctx):
    lib = default_library()
    base = ctx.fingerprint
    assert EvalContext(MCMAccelerator(1), lib, n_qor_samples=2).fingerprint == base
    # different accel / rank_genes / qor signature / library all re-key
    assert EvalContext(MCMAccelerator(0), lib, n_qor_samples=2).fingerprint != base
    assert EvalContext(MCMAccelerator(1), lib, rank_genes=True,
                       n_qor_samples=2).fingerprint != base
    assert EvalContext(MCMAccelerator(1), lib, n_qor_samples=3).fingerprint != base
    sub = lib.subset([c.name for c in lib.circuits[:40]])
    assert EvalContext(MCMAccelerator(1), sub, n_qor_samples=2).fingerprint != base


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

class _CountingCtx:
    """EvalContext stand-in with an observable, slowable ground truth."""

    def __init__(self, delay=0.0):
        self.fingerprint = "testctx"
        self.calls = []
        self.delay = delay
        self._lock = threading.Lock()

    def key(self, genome):
        return "g" + "-".join(str(int(v)) for v in np.atleast_1d(genome))

    def ground_truth(self, genomes):
        genomes = np.atleast_2d(genomes)
        with self._lock:
            self.calls.append(len(genomes))
        if self.delay:
            time.sleep(self.delay)
        n = len(genomes)
        val = genomes.sum(axis=1).astype(float)
        return {k: val.copy() for k in LABEL_KEYS}


def test_scheduler_store_hits_and_batching():
    store = InMemoryLabelStore()
    sched = EvalScheduler(store, n_workers=2, max_batch=8, max_wait_s=0.01)
    ctx = _CountingCtx()
    genomes = np.arange(12).reshape(6, 2)
    out = sched.label(ctx, genomes, campaign="a")
    assert np.array_equal(out["qor"], genomes.sum(axis=1).astype(float))
    assert sum(ctx.calls) == 6

    # identical batch again: all store hits, no new ground truth
    out2 = sched.label(ctx, genomes, campaign="b")
    assert np.array_equal(out2["qor"], out["qor"])
    assert sum(ctx.calls) == 6
    s = sched.stats()
    assert s["store_hits"] == 6 and s["labeled"] == 6
    assert s["per_campaign"]["b"]["store_hits"] == 6
    assert s["per_campaign"]["b"]["labeled"] == 0
    sched.shutdown()


def test_scheduler_inflight_dedup():
    """Two concurrent requests for one genome -> one ground-truth call."""
    store = InMemoryLabelStore()
    sched = EvalScheduler(store, n_workers=2, max_batch=8, max_wait_s=0.05)
    ctx = _CountingCtx(delay=0.2)
    genomes = np.array([[7, 7], [8, 8]])

    results = {}

    def ask(tag):
        results[tag] = sched.label(ctx, genomes, campaign=tag)

    t1 = threading.Thread(target=ask, args=("a",))
    t2 = threading.Thread(target=ask, args=("b",))
    t1.start(); t2.start(); t1.join(); t2.join()

    assert np.array_equal(results["a"]["qor"], results["b"]["qor"])
    # each unique genome synthesized exactly once across both campaigns
    assert sum(ctx.calls) == 2
    s = sched.stats()
    assert s["labeled"] == 2
    assert s["inflight_dedup_hits"] + s["store_hits"] == 2
    sched.shutdown()


def test_scheduler_duplicate_rows_one_call():
    """Duplicates WITHIN one submit dedupe in flight too."""
    store = InMemoryLabelStore()
    sched = EvalScheduler(store, n_workers=1, max_batch=8, max_wait_s=0.01)
    ctx = _CountingCtx()
    genomes = np.array([[1, 2], [1, 2], [1, 2], [3, 4]])
    out = sched.label(ctx, genomes)
    assert sum(ctx.calls) == 2
    assert out["qor"].tolist() == [3.0, 3.0, 3.0, 7.0]
    assert sched.stats()["inflight_dedup_hits"] == 2
    sched.shutdown()


# ---------------------------------------------------------------------------
# run_dse integration
# ---------------------------------------------------------------------------

def test_run_dse_injected_labeler_matches_default(ctx):
    accel, lib = ctx.accel, ctx.library
    cfg = small_cfg()
    ref = run_dse(accel, lib, cfg)

    store = InMemoryLabelStore()
    sched = EvalScheduler(store, n_workers=2, max_wait_s=0.005)
    res = run_dse(accel, lib, cfg,
                  labeler=lambda g: sched.label(ctx, g))
    assert np.array_equal(ref.front_genomes, res.front_genomes)
    assert np.allclose(ref.front_objectives, res.front_objectives)
    sched.shutdown()


def test_label_unique_scatters_back():
    calls = []

    def labeler(genomes):
        calls.append(len(genomes))
        v = genomes.sum(axis=1).astype(float)
        return {k: v for k in LABEL_KEYS}

    g = np.array([[3, 1], [0, 2], [3, 1], [0, 2], [0, 2]])
    out = label_unique(labeler, g)
    assert calls == [2]                      # only unique rows labeled
    assert out["qor"].tolist() == [4.0, 2.0, 4.0, 2.0, 2.0]


# ---------------------------------------------------------------------------
# campaigns
# ---------------------------------------------------------------------------

def test_two_concurrent_campaigns_share_labels(tmp_path):
    """Acceptance: two concurrent campaigns produce seed-identical
    fronts, every unique genome is synthesized once (in-flight dedup),
    and batches are coalesced across campaigns."""
    spec = CampaignSpec(accel="mcm2", **SMALL)
    ref = run_dse(MCMAccelerator(1), default_library(), spec.dse_config())

    mgr = CampaignManager(eval_workers=2, campaign_workers=2,
                          max_wait_s=0.02)
    c1, c2 = mgr.submit(spec), mgr.submit(spec)
    assert mgr.wait(c1, timeout=600) == "done"
    assert mgr.wait(c2, timeout=600) == "done"
    r1, r2 = mgr.result(c1), mgr.result(c2)

    assert np.array_equal(r1.front_genomes, r2.front_genomes)
    assert np.allclose(r1.front_objectives, ref.front_objectives)

    s = mgr.scheduler.stats()
    # both campaigns requested the same genomes; each was labeled once
    assert s["labeled"] < s["requests"]
    assert s["inflight_dedup_hits"] + s["store_hits"] > 0
    per = s["per_campaign"]
    total_saved = sum(v["store_hits"] + v["inflight_hits"] for v in per.values())
    assert total_saved >= s["labeled"]  # second campaign rode the first
    mgr.shutdown()


def test_second_campaign_cold_store_warm_rerun(tmp_path):
    """Acceptance: a rerun against a warm store performs zero
    ground-truth labeling (stage 1 AND stage 3 served from the store)."""
    path = str(tmp_path / "labels.jsonl")
    spec = CampaignSpec(accel="mcm2", **SMALL)

    store = JsonlLabelStore(path)
    mgr = CampaignManager(store, eval_workers=2, campaign_workers=1)
    cid = mgr.submit(spec)
    assert mgr.wait(cid, timeout=600) == "done"
    cold_front = mgr.result(cid).front_objectives
    cold_labeled = mgr.scheduler.stats()["labeled"]
    assert cold_labeled > 0
    mgr.shutdown()
    store.close()

    # fresh manager + fresh store instance on the same file (new "process")
    store2 = JsonlLabelStore(path)
    mgr2 = CampaignManager(store2, eval_workers=2, campaign_workers=1)
    cid2 = mgr2.submit(spec)
    assert mgr2.wait(cid2, timeout=600) == "done"
    s = mgr2.scheduler.stats()
    assert s["labeled"] == 0, "warm rerun paid ground truth"
    assert s["store_hits"] == s["requests"]
    assert np.allclose(mgr2.result(cid2).front_objectives, cold_front)
    mgr2.shutdown()
    store2.close()


def test_campaign_status_and_fronts():
    mgr = CampaignManager(eval_workers=2, campaign_workers=1)
    spec = CampaignSpec(accel="mcm2", **SMALL)
    cid = mgr.submit(spec)
    assert mgr.wait(cid, timeout=600) == "done"
    st = mgr.status(cid)
    assert st["state"] == "done" and st["front_size"] > 0
    assert st["labeling"]["requests"] > 0

    fr = mgr.front(cid)
    assert len(fr["front"]) == st["front_size"]
    gf = mgr.global_front("mcm2")
    assert 0 < len(gf["front"]) <= st["front_size"]
    assert gf["campaigns"] == [cid]
    assert mgr.global_front("mcm3")["front"] == []
    mgr.shutdown()


def test_global_front_skips_incompatible_contexts():
    """rank_genes changes the genome width, so campaigns with different
    eval contexts must not be merged into one front (and must not crash
    np.concatenate); the most recent context wins."""
    mgr = CampaignManager(eval_workers=2, campaign_workers=1)
    c1 = mgr.submit(CampaignSpec(accel="mcm2", **SMALL))
    assert mgr.wait(c1, timeout=600) == "done"
    c2 = mgr.submit(CampaignSpec(accel="mcm2", rank_genes=True, **SMALL))
    assert mgr.wait(c2, timeout=600) == "done"
    gf = mgr.global_front("mcm2")
    assert gf["campaigns"] == [c2]
    assert len(gf["front"]) > 0
    mgr.shutdown()


def test_campaign_retention_compacts_and_drops():
    """Old finished campaigns compact to their fronts, the very oldest
    are dropped entirely (incl. scheduler per-campaign accounting)."""
    from repro.service.campaigns import _CompactResult

    mgr = CampaignManager(eval_workers=2, campaign_workers=1,
                          keep_results=1, keep_campaigns=2)
    spec = CampaignSpec(accel="mcm2", **SMALL)
    # submit sequentially: retention evicts by FINISH order, which under
    # concurrent stepping is not necessarily submit order
    cids = []
    for _ in range(3):
        cid = mgr.submit(spec)
        assert mgr.wait(cid, timeout=600) == "done"
        cids.append(cid)

    with pytest.raises(KeyError):
        mgr.status(cids[0])                       # dropped
    assert cids[0] not in mgr.scheduler.stats()["per_campaign"]
    assert isinstance(mgr.result(cids[1]), _CompactResult)  # compacted
    assert len(mgr.front(cids[1])["front"]) > 0   # front still queryable
    assert not isinstance(mgr.result(cids[2]), _CompactResult)  # newest full
    assert len(mgr.global_front("mcm2")["front"]) > 0
    mgr.shutdown()


def test_submit_validates_spec_upfront():
    """Unknown accelerators / malformed sizes are rejected at submit time
    with a ValueError (-> HTTP 400) instead of failing asynchronously in
    a worker thread."""
    mgr = CampaignManager(eval_workers=1, campaign_workers=1)
    with pytest.raises(ValueError, match="unknown accelerator"):
        mgr.submit(CampaignSpec(accel="nope-such-accel", **SMALL))
    with pytest.raises(ValueError, match="n_train"):
        mgr.submit(CampaignSpec(accel="mcm2", **{**SMALL, "n_train": 0}))
    with pytest.raises(ValueError, match="n_parents"):
        mgr.submit(CampaignSpec(
            accel="mcm2", **{**SMALL, "pop_size": 4, "n_parents": 8}))
    with pytest.raises(ValueError, match="objectives"):
        mgr.submit(CampaignSpec(accel="mcm2",
                                objectives=("qor", "nope"), **SMALL))
    assert mgr.list_campaigns() == []    # nothing was admitted
    mgr.shutdown()


def test_campaign_failure_is_isolated():
    """A campaign that fails at RUN time (valid spec) is isolated: it
    lands in 'failed' with its error, without hurting the manager."""
    from repro.accel.base import Accelerator, Slot
    from repro.service import register_accelerator, unregister_accelerator

    class _Boom(Accelerator):
        name = "boom-accel"
        slots = [Slot("m0", "mul8u", 1.0)]

        def sample_inputs(self, n, seed=0):
            raise RuntimeError("boom at labeling time")

    register_accelerator("boom-accel", _Boom)
    mgr = CampaignManager(eval_workers=1, campaign_workers=1)
    try:
        cid = mgr.submit(CampaignSpec(accel="boom-accel", **SMALL))
        assert mgr.wait(cid, timeout=60) == "failed"
        assert "boom" in mgr.status(cid)["error"]
        with pytest.raises(RuntimeError):
            mgr.result(cid)
    finally:
        unregister_accelerator("boom-accel")
        mgr.shutdown()


def test_http_api_roundtrip():
    from repro.service.api import Client, make_server

    mgr = CampaignManager(eval_workers=2, campaign_workers=1)
    srv = make_server(mgr, port=0)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    try:
        cli = Client(f"http://127.0.0.1:{srv.server_address[1]}")
        assert cli._req("/healthz")["ok"]
        cid = cli.submit(accel="mcm2", **SMALL)
        st = cli.wait(cid, timeout=600)
        assert st["state"] == "done"
        assert len(cli.front(cid)["front"]) == st["front_size"]
        assert cli.global_front("mcm2")["campaigns"] == [cid]
        assert cli.stats()["scheduler"]["requests"] > 0
    finally:
        srv.shutdown()
        mgr.shutdown()
