"""HEVC 4x4 integer DCT — the paper's evaluation application (§IV).

The forward transform matrix (HEVC core transform, [25]):

    C = [[64,  64,  64,  64],
         [83,  36, -36, -83],
         [64, -64, -64,  64],
         [36, -83,  83, -36]]

Each output row i is one multiple-constant-multiplication block MCM_i:
four signed 8-bit multipliers (|constants| <= 83) + a 3-adder tree.  The
2-D transform applies the four MCMs column-wise, renormalizes (>>8, the
HEVC first-stage shift adapted to keep the 8-bit circuit domain), then
row-wise.  QoR = PSNR of the exact-IDCT reconstruction from approximate
coefficients vs the reconstruction from exact coefficients, over 4x4
blocks of the synthetic image set.

Adders run on 16-bit two's-complement patterns via ``signed16``.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.acl.library import Circuit, Library
from . import fused
from ._batchsim import grouped_apply, lut_gather, mul_lut
from .base import Accelerator, Slot
from .images import sample_images

__all__ = ["HEVC_C", "MCMAccelerator", "HEVCDct", "signed16"]

HEVC_C = np.array(
    [
        [64, 64, 64, 64],
        [83, 36, -36, -83],
        [64, -64, -64, 64],
        [36, -83, 83, -36],
    ],
    dtype=np.int64,
)

_SHIFT1 = 8  # stage-1 renormalization to stay in the signed 8-bit domain


def signed16(fn: Callable) -> Callable:
    """Lift an unsigned 16-bit adder model to signed two's complement:
    wrap to 16 bits, apply, sign-extend."""

    def wrapped(a, b):
        a16 = np.asarray(a, dtype=np.int64) & 0xFFFF
        b16 = np.asarray(b, dtype=np.int64) & 0xFFFF
        s = np.asarray(fn(a16, b16), dtype=np.int64) & 0xFFFF
        return np.where(s >= 0x8000, s - 0x10000, s)

    return wrapped


def _blocks(images: np.ndarray) -> np.ndarray:
    """(..., n, H, W) uint8 -> (..., m, 4, 4) signed residual blocks
    (pixel - 128); leading axes (e.g. a genome batch) pass through."""
    lead, (n, h, w) = images.shape[:-3], images.shape[-3:]
    h4, w4 = h - h % 4, w - w % 4
    x = images[..., :h4, :w4].reshape(lead + (n, h4 // 4, 4, w4 // 4, 4))
    x = np.moveaxis(x, -2, -3).reshape(lead + (-1, 4, 4))
    return x.astype(np.int64) - 128


def _mcm_apply(row: int, x: np.ndarray, muls, adds) -> np.ndarray:
    """y = sum_j C[row, j] * x[..., j] with per-slot circuits.

    x: (..., 4) signed 8-bit domain values."""
    coeffs = HEVC_C[row]
    # mul8s behavioral models are sign-magnitude wrapped: f(x, -c) = -f(x, c)
    prods = [muls[j](x[..., j], int(coeffs[j])) for j in range(4)]
    s0 = adds[0](prods[0], prods[1])
    s1 = adds[1](prods[2], prods[3])
    return adds[2](s0, s1)


def _rshift_round(v: np.ndarray, k: int) -> np.ndarray:
    return (v + (1 << (k - 1))) >> k


def _mcm_apply_batch(
    row: int,
    x: np.ndarray,
    mul_genes: np.ndarray,
    add_genes: np.ndarray,
    library: Library,
    *,
    per_genome: bool,
) -> np.ndarray:
    """Population MCM: products via one signed LUT gather (index = value
    + 128), adder tree grouped by distinct circuit.  ``x``: (..., 4)
    shared or (G, ..., 4) per-genome; returns (G, ...)."""
    lut = mul_lut(library, "mul8s", HEVC_C[row], tag=f"mcm{row}")
    prods = lut_gather(lut, mul_genes, x + 128, per_genome=per_genome)
    add_fns = [signed16(c.fn) for c in library.kind("add16")]
    s0 = grouped_apply(add_fns, add_genes[:, 0], prods[..., 0], prods[..., 1])
    s1 = grouped_apply(add_fns, add_genes[:, 1], prods[..., 2], prods[..., 3])
    return grouped_apply(add_fns, add_genes[:, 2], s0, s1)


class MCMAccelerator(Accelerator):
    """One MCM block (paper: MCM1..MCM4 of the HEVC use-case)."""

    batched_sim = True

    def __init__(self, row: int):
        assert 0 <= row < 4
        self.row = row
        self.name = f"mcm{row + 1}"
        self.slots = [Slot(f"mul{j}", "mul8s", 1.0) for j in range(4)] + [
            Slot(f"add{j}", "add16", 1.0) for j in range(3)
        ]

    def sample_inputs(self, n: int, seed: int = 0) -> np.ndarray:
        imgs = sample_images(n, size=32, seed=seed)
        return _blocks(imgs).reshape(-1, 4)  # row vectors of residuals

    def _decode(self, circuits: Sequence[Circuit]):
        muls = [c.fn for c in circuits[:4]]
        adds = [signed16(c.fn) for c in circuits[4:]]
        return muls, adds

    def simulate(self, circuits: Sequence[Circuit], inputs: np.ndarray) -> np.ndarray:
        muls, adds = self._decode(circuits)
        return _mcm_apply(self.row, inputs, muls, adds)

    def exact_output(self, inputs: np.ndarray) -> np.ndarray:
        return inputs @ HEVC_C[self.row]

    def simulate_batch(
        self,
        genomes: np.ndarray,
        library: Library,
        inputs: np.ndarray,
        *,
        rank_genes: bool = False,
        per_genome_inputs: bool = False,
    ) -> np.ndarray:
        fused_out = fused.try_simulate_batch(
            self, genomes, library, inputs,
            rank_genes=rank_genes, per_genome_inputs=per_genome_inputs,
        )
        if fused_out is not None:
            return fused_out
        genomes = np.atleast_2d(np.asarray(genomes, dtype=np.int64))
        return _mcm_apply_batch(
            self.row, np.asarray(inputs), genomes[:, :4], genomes[:, 4:7],
            library, per_genome=per_genome_inputs,
        )

    def matmul_shape(self) -> Tuple[int, int, int]:
        return (1024, 4, 1)

    def slot_groups(self) -> List[Tuple[int, int]]:
        return [(j, j + 1) for j in range(4)]

    def mul_slot_constants(self):
        return [int(c) for c in HEVC_C[self.row]]

    def deploy_signature(self, specs):
        from .base import grouped_deploy_signature

        return grouped_deploy_signature(self, specs)

    def build_deploy(self, specs: Sequence, inputs: Optional[np.ndarray] = None):
        import jax.numpy as jnp

        from ..kernels.approx_matmul import grouped_matmul

        if inputs is None:
            inputs = self.sample_inputs(1, seed=1)
        x = jnp.asarray(inputs)                              # (m, 4)
        w = jnp.asarray(HEVC_C[self.row].reshape(4, 1))      # signed constants
        groups = self.slot_groups()

        def fn(x, w):
            return grouped_matmul(x, w, specs, groups)

        return fn, (x, w)


class HEVCDct(Accelerator):
    """Full 2-D 4x4 approximate DCT: 16 mul8s + 12 add16 slots (four MCM
    blocks), applied column-wise then row-wise with a >>8 renorm."""

    name = "hevc_dct4x4"
    batched_sim = True
    deploy_passes = 2  # column stage + row stage

    def __init__(self):
        self.mcms = [MCMAccelerator(r) for r in range(4)]
        self.slots = []
        for m in self.mcms:
            self.slots += [
                Slot(f"{m.name}_{s.name}", s.kind, s.weight) for s in m.slots
            ]

    def sample_inputs(self, n: int, seed: int = 0) -> np.ndarray:
        return sample_images(n, size=32, seed=seed)

    def _split(self, circuits: Sequence[Circuit]):
        per = []
        for r in range(4):
            sub = circuits[r * 7 : (r + 1) * 7]
            muls = [c.fn for c in sub[:4]]
            adds = [signed16(c.fn) for c in sub[4:]]
            per.append((muls, adds))
        return per

    def _transform(self, blocks: np.ndarray, per) -> np.ndarray:
        """blocks: (..., m, 4, 4) -> coefficients (..., m, 4, 4)."""
        # stage 1: columns.  T[i, c] = MCM_i(X[:, c])
        t = np.stack(
            [
                _mcm_apply(r, np.swapaxes(blocks, -1, -2), per[r][0], per[r][1])
                for r in range(4)
            ],
            axis=-2,
        )  # (..., m, 4(row), 4(col))
        t = np.clip(_rshift_round(t, _SHIFT1), -128, 127)
        # stage 2: rows.  Y[i, k] = MCM_k(T[i, :])  (transform the rows)
        y = np.stack(
            [_mcm_apply(r, t, per[r][0], per[r][1]) for r in range(4)],
            axis=-1,
        )  # (..., m, 4, 4)
        return y

    def _reconstruct(self, coeffs: np.ndarray) -> np.ndarray:
        """Exact float inverse of the renormalized forward transform."""
        cinv = np.linalg.inv(HEVC_C.astype(np.float64))
        # forward was  Y ~= (C X C^T) / 2^8  (stage-1 shift); invert:
        x = cinv @ (coeffs.astype(np.float64) * (1 << _SHIFT1)) @ cinv.T
        return x

    def simulate(self, circuits: Sequence[Circuit], inputs: np.ndarray) -> np.ndarray:
        per = self._split(circuits)
        return self._reconstruct(self._transform(_blocks(inputs), per))

    def exact_output(self, inputs: np.ndarray) -> np.ndarray:
        exact = [
            ([lambda a, b: a * b] * 4, [lambda a, b: a + b] * 3) for _ in range(4)
        ]
        return self._reconstruct(self._transform(_blocks(inputs), exact))

    def _transform_batch(
        self,
        blocks: np.ndarray,
        genomes: np.ndarray,
        library: Library,
        *,
        per_genome: bool,
    ) -> np.ndarray:
        """Population transform: gene column 7r+j is MCM r's multiplier
        j, 7r+4+j its adder j (slot concatenation order)."""

        def mcm(r, x, per_g):
            return _mcm_apply_batch(
                r, x,
                genomes[:, 7 * r : 7 * r + 4],
                genomes[:, 7 * r + 4 : 7 * r + 7],
                library, per_genome=per_g,
            )

        xt = np.swapaxes(blocks, -1, -2)
        t = np.stack([mcm(r, xt, per_genome) for r in range(4)], axis=-2)
        t = np.clip(_rshift_round(t, _SHIFT1), -128, 127)
        # stage 2 sees the PER-GENOME intermediate t regardless of how
        # the population's input was shared
        y = np.stack([mcm(r, t, True) for r in range(4)], axis=-1)
        return y

    def simulate_batch(
        self,
        genomes: np.ndarray,
        library: Library,
        inputs: np.ndarray,
        *,
        rank_genes: bool = False,
        per_genome_inputs: bool = False,
    ) -> np.ndarray:
        fused_out = fused.try_simulate_batch(
            self, genomes, library, inputs,
            rank_genes=rank_genes, per_genome_inputs=per_genome_inputs,
        )
        if fused_out is not None:
            return fused_out
        genomes = np.atleast_2d(np.asarray(genomes, dtype=np.int64))
        coeffs = self._transform_batch(
            _blocks(np.asarray(inputs)), genomes, library,
            per_genome=per_genome_inputs,
        )
        return self._reconstruct(coeffs)

    def matmul_shape(self) -> Tuple[int, int, int]:
        return (1024, 4, 4)

    def slot_groups(self) -> List[Tuple[int, int]]:
        # mul slot j of MCM r contracts column j; groups returned MCM-major
        return [(j, j + 1) for _ in range(4) for j in range(4)]

    def mul_slot_constants(self):
        return [int(HEVC_C[r, j]) for r in range(4) for j in range(4)]

    def deploy_signature(self, specs):
        """The 2-D DCT deploys each spec as a (m,1)@(1,1) product in BOTH
        passes; the 16 slots are shape-interchangeable, so classes are
        the sorted multiset.  Its builder is not plain grouped_matmul —
        the family carries the class name (no cross-accelerator sharing)
        plus the canonical deploy input shape, which differs when the
        DCT runs in situ inside a pipeline (smaller intermediate images
        re-block to a different m)."""
        shape = getattr(self, "_native_input_shape", None)
        if shape is None:
            shape = np.shape(self.sample_inputs(1, seed=1))
            self._native_input_shape = shape
        family = ("hevc_dct4x4_2pass", shape,
                  tuple(int(v) for v in self.matmul_shape()))
        classes = tuple(sorted(
            (int(sp.rank), int(sp.trunc_bits), bool(sp.signed))
            for sp in specs
        ))
        return family, classes

    def build_deploy(self, specs: Sequence, inputs: Optional[np.ndarray] = None):
        """Deployment: two grouped matmuls (m,4)@(4,4) with per-(row, j)
        circuit specs, renorm between stages."""
        import jax.numpy as jnp

        from ..kernels.approx_matmul import approx_matmul

        if inputs is None:
            inputs = self.sample_inputs(1, seed=1)
        x = jnp.asarray(_blocks(inputs).reshape(-1, 4))  # (m*4, 4) rows
        w = jnp.asarray(HEVC_C.T)                        # (4, 4): col r = MCM_r

        def fn(x, w):
            outs = []
            for r in range(4):
                cols = []
                for j in range(4):
                    spec = specs[r * 4 + j]
                    cols.append(
                        approx_matmul(x[:, j : j + 1], w[j : j + 1, r : r + 1], spec)
                    )
                outs.append(sum(cols))
            y = jnp.concatenate(outs, axis=1)  # (m*4, 4) stage-1
            y = jnp.clip(jnp.round(y / (1 << _SHIFT1)), -128, 127)
            # stage 2 on the transposed intermediate (same circuit set)
            outs2 = []
            for r in range(4):
                cols = []
                for j in range(4):
                    spec = specs[r * 4 + j]
                    cols.append(
                        approx_matmul(
                            y[:, j : j + 1].astype(jnp.int32),
                            w[j : j + 1, r : r + 1],
                            spec,
                        )
                    )
                outs2.append(sum(cols))
            return jnp.concatenate(outs2, axis=1)

        return fn, (x, w)


# --- fused engine plans ----------------------------------------------------

def _mcm_fused_apply(eng, lut, x, mul_genes, add_genes, per_genome):
    """Traceable twin of ``_mcm_apply_batch``: x (..., 4) residuals
    (leading genome axis iff per_genome), returns (G, ...)."""
    G = mul_genes.shape[0]
    mid = x.shape[1:-1] if per_genome else x.shape[:-1]
    cols = x + 128
    cols = cols.reshape((G, -1, 4)) if per_genome else cols.reshape((-1, 4))
    prods = eng.gather(lut, mul_genes, cols, per_genome=per_genome)
    s0 = eng.select_add(add_genes[:, 0], prods[..., 0], prods[..., 1], signed=True)
    s1 = eng.select_add(add_genes[:, 1], prods[..., 2], prods[..., 3], signed=True)
    out = eng.select_add(add_genes[:, 2], s0, s1, signed=True)
    return out.reshape((G,) + mid)


def _blocks_fused(images):
    """Traceable twin of ``_blocks`` (int32 domain)."""
    import jax.numpy as jnp

    lead, (n, h, w) = images.shape[:-3], images.shape[-3:]
    h4, w4 = h - h % 4, w - w % 4
    x = images[..., :h4, :w4].reshape(lead + (n, h4 // 4, 4, w4 // 4, 4))
    x = jnp.moveaxis(x, -2, -3).reshape(lead + (-1, 4, 4))
    return x - 128


def _prep_i32(inputs):
    return np.ascontiguousarray(np.asarray(inputs), dtype=np.int32)


@fused.register_fused(MCMAccelerator)
def _mcm_fused_plan(accel, library, eng):
    """Single-MCM XLA program; integer outputs, so QoR reduces on-device
    against the exact ``inputs @ C[row]``."""
    lut = eng.lut("mul8s", HEVC_C[accel.row], tag=f"mcm{accel.row}")

    def stage_fn(genes, x, per_genome):
        return _mcm_fused_apply(
            eng, lut, x, genes[:, :4], genes[:, 4:7], per_genome
        )

    return fused.FusedPlan(
        key=(),
        stage_fn=stage_fn,
        prep=_prep_i32,
        post=lambda raw, inputs, per_genome: raw.astype(np.int64),
        qor_ref=lambda a, inputs: np.asarray(a.exact_output(inputs)),
    )


@fused.register_fused(HEVCDct)
def _hevc_fused_plan(accel, library, eng):
    """Full 2-D DCT as one XLA program: in-jit blocking, both MCM
    passes, renorm/clip between.  The device returns the INTEGER
    coefficients; the float64 inverse-transform tail stays on the host
    (``_reconstruct``) because float64 matmul contraction order — and
    hence bits — is BLAS/XLA-implementation-defined, while the host
    path is shared with the numpy engine verbatim."""
    import jax.numpy as jnp

    luts = [eng.lut("mul8s", HEVC_C[r], tag=f"mcm{r}") for r in range(4)]

    def stage_fn(genes, x, per_genome):
        blocks = _blocks_fused(x)

        def mcm(r, v, per_g):
            return _mcm_fused_apply(
                eng, luts[r], v,
                genes[:, 7 * r : 7 * r + 4],
                genes[:, 7 * r + 4 : 7 * r + 7],
                per_g,
            )

        xt = jnp.swapaxes(blocks, -1, -2)
        t = jnp.stack([mcm(r, xt, per_genome) for r in range(4)], axis=-2)
        t = jnp.clip((t + (1 << (_SHIFT1 - 1))) >> _SHIFT1, -128, 127)
        y = jnp.stack([mcm(r, t, True) for r in range(4)], axis=-1)
        return y  # integer coefficients (G, ..., m, 4, 4)

    return fused.FusedPlan(
        key=(),
        stage_fn=stage_fn,
        prep=_prep_i32,
        post=lambda raw, inputs, per_genome: accel._reconstruct(
            raw.astype(np.int64)
        ),
        qor_ref=None,
        device_natural=False,
    )
