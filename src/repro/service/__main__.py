"""Entry point: ``PYTHONPATH=src python -m repro.service``.

Starts the campaign service with a persistent on-disk label store —
every ground-truth label any campaign pays for is reused by all later
campaigns, across restarts."""

from __future__ import annotations

import argparse

from .. import obs
from .api import serve
from .campaigns import CampaignManager
from .store import open_label_store


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="Pareto-as-a-service: concurrent DSE campaigns with a "
                    "persistent label store and coalesced evaluation batching",
    )
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8177)
    ap.add_argument("--store", default="runs/service_labels.jsonl",
                    help="JSONL label-store path (persistent across runs)")
    ap.add_argument("--synth-cache", default="runs/service_synth.jsonl",
                    help="persistent structural compile cache (JSONL "
                         "sidecar next to the label store): warm runs, "
                         "restarted services and every process-pool "
                         "labeler worker share one compile pool; '' "
                         "disables persistence (in-process sharing only)")
    ap.add_argument("--eval-workers", type=int, default=2,
                    help="ground-truth labeling worker threads")
    ap.add_argument("--eval-backend", choices=("thread", "process", "fleet"),
                    default="thread",
                    help="where batched ground truth runs: in-process "
                         "threads, a spawn-safe process pool (parallelizes "
                         "the GIL-bound behavioral sim + XLA tracing on one "
                         "host), or a multi-host labeling fleet (remote "
                         "workers join via 'python -m repro.fleet.worker "
                         "--orchestrator http://this-host:port')")
    ap.add_argument("--process-workers", type=int, default=None,
                    help="process-pool size (default: --eval-workers)")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="genomes per process-pool chunk (default: "
                         "auto, ~2 chunks per worker)")
    ap.add_argument("--fleet-fallback", choices=("thread", "process"),
                    default="thread",
                    help="in-process backend used when the fleet is empty "
                         "or a context cannot cross hosts")
    ap.add_argument("--lease-ttl", type=float, default=30.0,
                    help="seconds a fleet worker may hold a leased chunk "
                         "before it requeues")
    ap.add_argument("--heartbeat-ttl", type=float, default=15.0,
                    help="seconds of heartbeat silence before a fleet "
                         "worker is declared dead (its leases requeue)")
    ap.add_argument("--fleet-chunk", type=int, default=None,
                    help="genomes per fleet lease (default: auto, ~2 "
                         "chunks per live worker)")
    ap.add_argument("--campaign-workers", type=int, default=2,
                    help="campaign stepper threads (campaigns multiplex "
                         "cooperatively, so many more campaigns than "
                         "workers can be in flight)")
    ap.add_argument("--snapshots", default="runs/service_snapshots.jsonl",
                    help="campaign snapshot file: killed campaigns are "
                         "resumable via POST /campaigns/<id>/resume after "
                         "a restart ('' disables)")
    ap.add_argument("--hier-workers", type=int, default=1,
                    help="concurrently running hierarchical jobs (their "
                         "per-stage campaigns use the campaign workers)")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="max label requests coalesced per batch")
    ap.add_argument("--max-wait-ms", type=float, default=20.0,
                    help="batch admission window (milliseconds)")
    ap.add_argument("--log-level", default=None,
                    choices=("debug", "info", "warning", "error"),
                    help="log verbosity (default: info; every record "
                         "carries campaign/worker correlation ids)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="append finished spans as JSON lines; export a "
                         "Perfetto-loadable trace with 'python -m "
                         "repro.obs.export PATH --chrome-trace'")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    obs.setup_logging(args.log_level
                      or ("debug" if args.verbose else "info"))
    log = obs.get_logger("repro.service")
    if args.trace:
        obs.set_sink(args.trace)
        log.info("tracing to %s", args.trace)

    store = open_label_store(args.store, migrate=True)
    log.info("label store %s: %d entries", args.store, len(store))
    manager = CampaignManager(
        store,
        eval_workers=args.eval_workers,
        eval_backend=args.eval_backend,
        process_workers=args.process_workers,
        chunk_size=args.chunk_size,
        fleet_fallback=args.fleet_fallback,
        lease_ttl_s=args.lease_ttl,
        heartbeat_ttl_s=args.heartbeat_ttl,
        fleet_chunk=args.fleet_chunk,
        campaign_workers=args.campaign_workers,
        hier_workers=args.hier_workers,
        max_batch=args.max_batch,
        max_wait_s=args.max_wait_ms / 1e3,
        snapshot_path=args.snapshots or None,
        synth_cache=args.synth_cache or None,
    )
    if manager.synth_cache is not None:
        log.info("synth cache %s: %d compiled structures",
                 args.synth_cache, len(manager.synth_cache))
    if args.snapshots:
        resumable = manager.snapshot_ids()
        if resumable:
            log.info("%d resumable campaign(s): %s",
                     len(resumable), ", ".join(resumable))
    if args.eval_backend == "fleet":
        log.info(
            "fleet orchestrator mounted at POST /fleet/* — join workers "
            "with: python -m repro.fleet.worker --orchestrator "
            "http://%s:%s --store %s%s",
            args.host, args.port, args.store,
            f" --synth-cache {args.synth_cache}" if args.synth_cache else "",
        )
    serve(manager, args.host, args.port, quiet=not args.verbose)


if __name__ == "__main__":
    main()
